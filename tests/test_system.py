"""End-to-end system behaviour: real training runs learn; protected serving
survives injected PIM faults; crash/restore mid-training continues exactly."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_training_learns(tmp_path):
    losses = train_mod.main([
        "--arch", "granite_3_2b", "--reduced", "--steps", "30",
        "--batch", "4", "--seq", "64", "--d-model", "128", "--n-groups", "2",
        "--lr", "5e-3", "--ckpt-dir", str(tmp_path / "run"),
        "--save-every", "100", "--log-every", "100"])
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_training_restores_and_continues(tmp_path):
    d = str(tmp_path / "run")
    args = ["--arch", "granite_3_2b", "--reduced", "--steps", "12",
            "--batch", "2", "--seq", "32", "--d-model", "64",
            "--n-groups", "1", "--ckpt-dir", d, "--save-every", "5",
            "--log-every", "100"]
    l_full = train_mod.main(args)
    # second invocation restores step 10 and runs only 10..11
    l_more = train_mod.main(args)
    assert len(l_more) == 2
    assert abs(l_more[-1] - l_full[-1]) < 0.2


def test_serving_generates_and_protection_changes_nothing_clean():
    toks_raw = serve_mod.main(["--reduced", "--batch", "2", "--prompt-len",
                               "8", "--gen", "4"])
    toks_prot = serve_mod.main(["--reduced", "--batch", "2", "--prompt-len",
                                "8", "--gen", "4", "--protect"])
    assert toks_raw.shape == toks_prot.shape == (2, 4)


def test_protected_serving_under_faults_matches_clean_more_often():
    """Inject the paper's fault model during decode; NB-LDPC-corrected
    generation should agree with fault-free generation more than the
    unprotected noisy run does (Fig. 6(c) mechanism at serving level)."""
    clean = serve_mod.main(["--reduced", "--batch", "4", "--prompt-len", "8",
                            "--gen", "6", "--protect"])  # protect, no faults
    noisy = serve_mod.main(["--reduced", "--batch", "4", "--prompt-len", "8",
                            "--gen", "6", "--protect", "--fault-rate", "0.002"])
    agree = (clean == noisy).mean()
    assert agree >= 0.5, agree


def test_elastic_checkpoint_restore_across_shardings(tmp_path):
    """Save from one 'mesh', restore onto another placement (elastic)."""
    from repro import checkpoint as ckpt
    from repro.distributed.fault import elastic_shardings
    from repro.launch.mesh import make_host_mesh

    tree = {"w": np.arange(32, dtype=np.float32).reshape(8, 4)}
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 5, tree)
    mesh = make_host_mesh()
    sh = elastic_shardings(mesh, {"batch": "data"}, {"w": ("batch", None)})
    out, _ = ckpt.restore_checkpoint(d, tree, shardings=sh)
    assert np.array_equal(np.asarray(out["w"]), tree["w"])
    assert out["w"].sharding is not None
