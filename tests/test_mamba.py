"""Mamba: chunked associative scan vs sequential oracle; decode step parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.mamba import (MambaState, _causal_conv_full, _ssm_chunked,
                            _ssm_step, init_mamba, init_mamba_state,
                            mamba_apply)


def _ssm_sequential(u, delta, A, B, C, D, h0):
    """Step-by-step oracle for the selective scan."""
    Bb, L, di = u.shape
    h = np.asarray(h0, np.float64)
    ys = []
    for t in range(L):
        dA = np.exp(np.asarray(delta[:, t], np.float64)[..., None] *
                    np.asarray(A, np.float64))
        dBu = (np.asarray(delta[:, t] * u[:, t], np.float64)[..., None]
               * np.asarray(B[:, t], np.float64)[:, None, :])
        h = dA * h + dBu
        ys.append(np.einsum("bds,bs->bd", h, np.asarray(C[:, t], np.float64)))
    y = np.stack(ys, 1) + np.asarray(u, np.float64) * np.asarray(D, np.float64)
    return y, h


@pytest.mark.parametrize("L,chunk", [(8, 4), (16, 16), (24, 8), (7, 16)])
def test_chunked_scan_matches_sequential(rng, L, chunk):
    Bb, di, ds = 2, 8, 4
    u = jnp.asarray(rng.normal(size=(Bb, L, di)).astype(np.float32))
    delta = jnp.asarray(0.1 + 0.2 * rng.random((Bb, L, di)).astype(np.float32))
    A = jnp.asarray(-0.5 - rng.random((di, ds)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(Bb, L, ds)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(Bb, L, ds)).astype(np.float32))
    D = jnp.ones((di,), jnp.float32)
    h0 = jnp.zeros((Bb, di, ds), jnp.float32)
    Lp = L if L % chunk == 0 else L + (chunk - L % chunk)
    pad = lambda t: jnp.pad(t, ((0, 0), (0, Lp - L)) + ((0, 0),) * (t.ndim - 2))
    y, h = _ssm_chunked(pad(u), pad(delta), A, pad(B), pad(C), D, h0,
                        min(chunk, Lp))
    y_ref, h_ref = _ssm_sequential(u, delta, A, B, C, D, h0)
    np.testing.assert_allclose(np.asarray(y)[:, :L], y_ref, rtol=2e-4,
                               atol=2e-4)


def test_decode_step_continues_full_scan(rng):
    """Running L steps one-by-one must equal the full-sequence scan."""
    cfg = get_config("falcon_mamba_7b").reduced()
    key = jax.random.PRNGKey(0)
    params = init_mamba(key, cfg)
    B, L = 2, 6
    x = jnp.asarray(rng.normal(size=(B, L, cfg.d_model)).astype(np.float32))
    y_full, st_full = mamba_apply(params, x.astype(jnp.bfloat16), cfg)

    st = init_mamba_state(cfg, B)
    ys = []
    for t in range(L):
        y_t, st = mamba_apply(params, x[:, t:t + 1].astype(jnp.bfloat16), cfg,
                              state=st, decode=True)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=0.15, atol=0.05)
    np.testing.assert_allclose(np.asarray(st_full.ssm), np.asarray(st.ssm),
                               rtol=0.1, atol=0.05)


def test_causal_conv_tail_carry(rng):
    K, di, B, L = 4, 6, 2, 10
    x = jnp.asarray(rng.normal(size=(B, L, di)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, di)).astype(np.float32))
    b = jnp.zeros((di,), jnp.float32)
    full, _ = _causal_conv_full(x, w, b)
    # split into two segments carrying the tail
    y1, tail = _causal_conv_full(x[:, :6], w, b)
    y2, _ = _causal_conv_full(x[:, 6:], w, b, tail)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)
