"""The paper's technique end-to-end: protected PIM matmul + PIM-mode
detection linearity (Eq. 4/5) + PIMContext integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PIMConfig, ProtectionConfig, encode_weight_matrix,
                        get_code, pim_mac, protected_pim_matmul, syndrome)
from repro.core.context import PIMContext
from repro.core.protected import prepare_weights
from repro.configs.base import PIMSpec


def test_pim_mode_detection_linearity(rng):
    """Y' = X·W' satisfies Y'·Hc^T == 0 mod p iff no error (paper Eq. 5)."""
    code = get_code("wl40_r08")
    n_in = 24
    W = jnp.asarray(rng.integers(-1, 2, (n_in, 2 * code.k)), jnp.int32)
    W_enc = encode_weight_matrix(W, code)
    x = jnp.asarray(rng.integers(-1, 2, (6, n_in)), jnp.int32)
    Y = pim_mac(x, W_enc, PIMConfig())                     # clean MAC
    yb = Y.reshape(-1, code.n)
    assert not np.asarray(syndrome(yb % code.p, code)).any()
    # inject an arithmetic error on one output integer -> detected
    Y_bad = Y.at[2, 5].add(1)
    s = syndrome(Y_bad.reshape(-1, code.n) % code.p, code)
    assert np.asarray(s).any()


@pytest.mark.parametrize("n_err", [1, 2, 4])
def test_protected_matmul_corrects_output_errors(rng, n_err):
    code = get_code("wl160_r08")
    n_in, B = 32, 4
    W = jnp.asarray(rng.integers(-1, 2, (n_in, code.k)), jnp.int32)
    W_enc = encode_weight_matrix(W, code)
    x = jnp.asarray(rng.integers(-1, 2, (B, n_in)), jnp.int32)
    exact = (x @ W).astype(jnp.int32)

    prot = ProtectionConfig(mode="correct", n_iters=10, damping=0.3)
    cfgp = PIMConfig()

    # corrupt the MAC output manually: protected path must undo it
    Y = pim_mac(x, W_enc, cfgp)
    Yc = np.asarray(Y).copy()
    for b in range(B):
        idx = rng.choice(code.n, n_err, replace=False)
        Yc[b, idx] += rng.choice([-1, 1], n_err)

    from repro.core.decode import decode_integers
    y_corr, res = decode_integers(code, jnp.asarray(Yc), n_iters=10,
                                  damping=0.3)
    data = np.asarray(y_corr)[:, :code.k]
    frac = (data == np.asarray(exact)).mean()
    assert frac > 0.99, f"corrected fraction {frac}"


def test_protected_matmul_modes(rng):
    code = get_code("wl40_r08")
    W = jnp.asarray(rng.integers(-1, 2, (16, code.k)), jnp.int32)
    W_enc = encode_weight_matrix(W, code)
    x = jnp.asarray(rng.integers(-1, 2, (3, 16)), jnp.int32)
    exact = np.asarray(x @ W)
    for mode in ("off", "detect", "correct"):
        res = protected_pim_matmul(x, W_enc, code,
                                   ProtectionConfig(mode=mode), PIMConfig())
        assert (np.asarray(res.y) == exact).all()
        if mode != "off":
            assert not np.asarray(res.detected).any()


def test_protected_with_injected_faults_beats_unprotected(rng):
    """Fig. 6(c) mechanism: with stochastic output faults, ECC recovers most
    integers; without it they stay wrong."""
    code = get_code("wl160_r08")
    n_in, B = 48, 8
    W = jnp.asarray(rng.integers(-1, 2, (n_in, code.k)), jnp.int32)
    W_enc = encode_weight_matrix(W, code)
    x = jnp.asarray(rng.integers(-1, 2, (B, n_in)), jnp.int32)
    exact = np.asarray(x @ W)

    cfg_noisy = PIMConfig(output_error_rate=0.01, output_error_mag=1)
    key = jax.random.PRNGKey(5)
    raw = protected_pim_matmul(x, W_enc, code, ProtectionConfig(mode="off"),
                               cfg_noisy, key=key)
    cor = protected_pim_matmul(x, W_enc, code,
                               ProtectionConfig(mode="correct", n_iters=10,
                                                damping=0.3),
                               cfg_noisy, key=key)
    err_raw = (np.asarray(raw.y) != exact).mean()
    err_cor = (np.asarray(cor.y) != exact).mean()
    assert err_raw > 0
    assert err_cor < err_raw / 2, (err_raw, err_cor)


def test_prepare_weights_pads(rng):
    code = get_code("wl40_r08")
    W = jnp.asarray(rng.integers(-1, 2, (8, code.k + 5)), jnp.int32)
    W_enc = prepare_weights(W, code)
    assert W_enc.shape[1] == 2 * code.n


def test_pim_context_matmul_close_to_float(rng):
    spec = PIMSpec(enabled=True, code_name="wl40_r08", mode="correct",
                   n_iters=4)
    ctx = PIMContext(spec)
    x = jnp.asarray(rng.normal(size=(4, 10, 24)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(24, 48)).astype(np.float32))
    y = ctx.matmul(x, W, "mlp_down")
    assert y.shape == (4, 10, 48)
    ref = np.asarray(x) @ np.asarray(W)
    corr = np.corrcoef(np.asarray(y, np.float32).ravel(), ref.ravel())[0, 1]
    assert corr > 0.75, corr       # ternary+int quantization keeps structure


def test_pim_context_fault_injection_deterministic(rng):
    spec = PIMSpec(enabled=True, code_name="wl40_r08", mode="off")
    ctx = PIMContext(spec).with_faults(jax.random.PRNGKey(0), 0.05)
    x = jnp.asarray(rng.normal(size=(2, 4, 16)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    y1 = ctx.matmul(x, W, "a")
    y2 = ctx.matmul(x, W, "a")
    assert (np.asarray(y1) == np.asarray(y2)).all()
