"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("N", [8, 48, 512, 1000])
@pytest.mark.parametrize("dc", [3, 6, 16])
@pytest.mark.parametrize("p", [2, 3, 5, 7])
def test_fbp_kernel_matches_ref(rng, N, dc, p):
    m = jnp.asarray(rng.normal(size=(N, dc, p)).astype(np.float32))
    out_k = ops.fbp_cn(m, p)
    out_r = ref.fbp_cn_ref(m, p)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_fbp_kernel_with_identity_padding(rng):
    from repro.core.llv import NEG_INF
    p, N, dc = 3, 64, 8
    m = np.full((N, dc, p), NEG_INF, np.float32)
    m[..., 0] = 0.0
    m[:, :5, :] = rng.normal(size=(N, 5, p))
    m = jnp.asarray(m)
    np.testing.assert_allclose(np.asarray(ops.fbp_cn(m, p)),
                               np.asarray(ref.fbp_cn_ref(m, p)), rtol=1e-6)


@pytest.mark.parametrize("M,K,N", [(8, 8, 8), (70, 130, 50), (128, 128, 128),
                                   (256, 320, 64), (1, 512, 1)])
@pytest.mark.parametrize("p", [2, 3, 7])
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32])
def test_gf_matmul_matches_ref(rng, M, K, N, p, dtype):
    assert K * (p - 1) ** 2 < 2 ** 31   # int32 kernel accumulator bound
    a = jnp.asarray(rng.integers(0, p, (M, K)), dtype)
    b = jnp.asarray(rng.integers(0, p, (K, N)), dtype)
    out_k = ops.gf_matmul(a, b, p)
    out_r = ref.gf_matmul_ref(a, b, p)
    assert (np.asarray(out_k) == np.asarray(out_r)).all()
    assert (np.asarray(out_k) < p).all() and (np.asarray(out_k) >= 0).all()


@pytest.mark.parametrize("M,K,C", [(8, 8, 8), (37, 80, 16), (128, 128, 128),
                                   (200, 320, 60), (1, 512, 3)])
@pytest.mark.parametrize("p", [2, 3, 7])
def test_scan_syndromes_matches_ref(rng, M, K, C, p):
    assert K * (p - 1) ** 2 < 2 ** 31   # int32 kernel accumulator bound
    y = jnp.asarray(rng.integers(0, p, (M, K)), jnp.int32)
    ht = jnp.asarray(rng.integers(0, p, (K, C)), jnp.int32)
    # plant guaranteed-clean rows so the test discriminates (zero words have
    # zero syndrome under any H)
    y = y.at[::3].set(0)
    out = np.asarray(ops.scan_syndromes(y, ht, p))
    exp = np.asarray(ref.scan_syndromes_ref(y, ht, p))
    assert out.shape == (M,) and out.dtype == bool
    assert (out == exp).all()
    assert not out[::3].any()


def test_scan_syndromes_codeword_sensitivity(rng):
    """Valid codewords never flag; any single-cell hit always flags (H has
    no zero columns by construction, dv >= 3)."""
    from repro.core import get_code, np_encode_words
    code = get_code("wl80_r08")
    assert code.n * (code.p - 1) ** 2 < 2 ** 31   # int32 accumulator bound
    w = rng.integers(0, code.p, (32, code.k))
    enc = np_encode_words(w, code)
    ht = jnp.asarray(code.H.T, jnp.int32)
    clean = np.asarray(ops.scan_syndromes(jnp.asarray(enc, jnp.int32),
                                          ht, code.p))
    assert not clean.any()
    hit = enc.copy()
    cols = rng.integers(0, code.n, 32)
    hit[np.arange(32), cols] = (hit[np.arange(32), cols] + 1) % code.p
    flagged = np.asarray(ops.scan_syndromes(jnp.asarray(hit, jnp.int32),
                                            ht, code.p))
    assert flagged.all()


@pytest.mark.parametrize("B,K,N", [(4, 64, 16), (16, 96, 40), (128, 256, 128)])
@pytest.mark.parametrize("R,adc", [(0, 0), (32, 0), (32, 7), (16, 15)])
def test_pim_mac_matches_ref(rng, B, K, N, R, adc):
    x = jnp.asarray(rng.integers(-1, 2, (B, K)), jnp.int32)
    w = jnp.asarray(rng.integers(-1, 2, (K, N)), jnp.int32)
    out_k = ops.pim_mac(x, w, row_parallelism=R, adc_levels=adc)
    out_r = ref.pim_mac_ref(x, w, row_parallelism=R if R else K,
                            adc_levels=adc)
    assert (np.asarray(out_k) == np.asarray(out_r)).all()


def test_pim_mac_saturation_effect(rng):
    """ADC clipping must actually clip when partial sums exceed the range."""
    x = jnp.ones((2, 64), jnp.int32)
    w = jnp.ones((64, 4), jnp.int32)
    exact = ops.pim_mac(x, w, row_parallelism=0, adc_levels=0)
    clipped = ops.pim_mac(x, w, row_parallelism=32, adc_levels=7)
    assert (np.asarray(exact) == 64).all()
    assert (np.asarray(clipped) == 6).all()     # 2 groups x clip(32->3)=3? no:
    # each 32-row group sums to 32, clips to adc_levels//2 = 3 -> 2 groups = 6


def test_fbp_batched_adapter(rng):
    from repro.core.decode import _cn_fbp_jnp
    from repro.kernels.ops import fbp_cn_batched
    m = jnp.asarray(rng.normal(size=(4, 6, 5, 3)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fbp_cn_batched(m, 3)),
                               np.asarray(_cn_fbp_jnp(m, 3)), rtol=1e-6)


def test_decoder_with_pallas_cn_path(rng):
    """Full decode pipeline dispatching CN work to the Pallas kernel."""
    from repro.core import decode_integers, encode_words, get_code
    from repro.kernels.ops import fbp_cn_batched
    code = get_code("wl40_r08")
    w = jnp.asarray(rng.integers(0, code.p, (8, code.k)))
    cw = encode_words(w, code)
    y = np.asarray(cw).copy()
    y[:, 3] += 1
    ya, _ = decode_integers(code, jnp.asarray(y), n_iters=8, damping=0.3)
    yb, _ = decode_integers(code, jnp.asarray(y), n_iters=8, damping=0.3,
                            cn_fbp=fbp_cn_batched)
    assert (np.asarray(ya) == np.asarray(yb)).all()
    assert (np.asarray(yb) == np.asarray(cw)).all()
