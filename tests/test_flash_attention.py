"""Flash-attention Pallas kernels vs the naive oracle (interpret mode):
shape/dtype/mask sweeps for fwd and grads, plus the model-level dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention
from repro.kernels.ref import flash_attention_ref

CASES = [
    # B, Sq, Skv, Hq, Hkv, D, causal, window, softcap
    (1, 16, 16, 2, 2, 8, True, 0, 0.0),
    (2, 32, 32, 4, 2, 16, True, 0, 0.0),       # GQA
    (1, 64, 64, 4, 4, 8, True, 16, 0.0),       # sliding window
    (2, 32, 48, 4, 2, 8, False, 0, 0.0),       # cross / bidirectional
    (1, 32, 32, 2, 2, 8, True, 0, 30.0),       # soft-cap (gemma2)
    (1, 100, 100, 4, 2, 8, True, 0, 0.0),      # ragged
]


def _mk(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


@pytest.mark.parametrize("case", CASES)
def test_forward_matches_oracle(rng, case):
    B, Sq, Skv, Hq, Hkv, D, causal, win, cap = case
    q = _mk(rng, B, Sq, Hq, D)
    k = _mk(rng, B, Skv, Hkv, D)
    v = _mk(rng, B, Skv, Hkv, D)
    o = flash_attention(q, k, v, causal, win, cap, None, True)
    o_ref = flash_attention_ref(q, k, v, causal=causal, window=win,
                                softcap=cap)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("case", CASES[:4])
def test_grads_match_oracle(rng, case):
    B, Sq, Skv, Hq, Hkv, D, causal, win, cap = case
    q = _mk(rng, B, Sq, Hq, D)
    k = _mk(rng, B, Skv, Hkv, D)
    v = _mk(rng, B, Skv, Hkv, D)

    def f1(q, k, v):
        return (flash_attention(q, k, v, causal, win, cap, None, True)
                ** 2).sum()

    def f2(q, k, v):
        return (flash_attention_ref(q, k, v, causal=causal, window=win,
                                    softcap=cap) ** 2).sum()

    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2, strict=True):
        scale = float(jnp.max(jnp.abs(b))) + 1e-9
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale, atol=5e-3)


def test_bf16_inputs(rng):
    q = _mk(rng, 1, 32, 2, 8).astype(jnp.bfloat16)
    k = _mk(rng, 1, 32, 2, 8).astype(jnp.bfloat16)
    v = _mk(rng, 1, 32, 2, 8).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, True, 0, 0.0, None, True)
    o_ref = flash_attention_ref(q, k, v, causal=True)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(o_ref),
                               rtol=0.05, atol=0.05)


def test_model_level_flash_equals_naive():
    from repro.configs import get_config
    from repro.models import forward, init_params
    cfg = get_config("gemma2_27b").reduced()   # window + softcap + GQA
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    l1 = forward(params, cfg, tokens)
    l2 = forward(params, dataclasses.replace(cfg, attn_impl="flash"), tokens)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 0.05
