"""Hypothesis import shim so the suite collects without the dependency.

CI installs real `hypothesis` (see requirements.txt) and gets full
property-based testing. In bare containers where it is absent, this module
provides a deterministic drop-in subset: `@given` expands each strategy into
a fixed pseudo-random sample grid (seeded, so runs are reproducible) and
invokes the test once per sample tuple. Only the strategy surface the test
suite actually uses is implemented (`st.integers`, `st.sampled_from`).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback sampler
    import inspect
    import itertools

    import numpy as _np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5

    class _IntStrategy:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def sample(self, rng, k: int):
            span = self.hi - self.lo + 1
            if span <= k:
                return list(range(self.lo, self.hi + 1))
            picks = {self.lo, self.hi}
            while len(picks) < k:
                picks.add(int(rng.integers(self.lo, self.hi + 1)))
            return sorted(picks)

    class _ChoiceStrategy:
        def __init__(self, options):
            self.options = list(options)

        def sample(self, rng, k: int):
            if len(self.options) <= k:
                return list(self.options)
            idx = rng.choice(len(self.options), size=k, replace=False)
            return [self.options[i] for i in sorted(idx)]

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

        @staticmethod
        def sampled_from(options) -> _ChoiceStrategy:
            return _ChoiceStrategy(options)

    st = _Strategies()

    def given(*strategies):
        """Bind strategies to the test's trailing parameters (hypothesis
        semantics) and expand them into a deterministic sample product.

        The wrapper's visible signature drops the bound parameters so pytest
        does not mistake them for fixtures; remaining leading parameters
        (e.g. the `rng` fixture) pass through untouched.
        """
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            bound = params[len(params) - len(strategies):]
            names = [p.name for p in bound]

            def wrapper(*args, **kwargs):
                rng = _np.random.default_rng(0)
                grids = [s.sample(rng, _FALLBACK_EXAMPLES) for s in strategies]
                for values in itertools.product(*grids):
                    call_kwargs = dict(kwargs)
                    call_kwargs.update(zip(names, values, strict=True))
                    fn(*args, **call_kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__signature__ = sig.replace(
                parameters=params[:len(params) - len(strategies)])
            return wrapper
        return deco

    def settings(**_kwargs):
        def deco(fn):
            return fn
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
