"""Runtime sanitizer (`repro.analysis.use_sanitizer`): the GF/attention
entry points pass corrupted inputs through *silently* when the sanitizer
is off, and raise `SanitizerError` when it is on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (SanitizerError, check_finite, check_gf_symbols,
                            check_quant_scales, sanitizer_enabled,
                            use_sanitizer)
from repro.core import get_code
from repro.core.decode import decode_integers
from repro.kernels import ops
from repro.models.kv import ProtectedKVConfig, ProtectedKVLayer


@pytest.fixture(autouse=True)
def _sanitizer_off():
    """Pin the ambient off so the silent/raising pairs below stay
    deterministic even under a REPRO_SANITIZE=1 (CI smoke) environment."""
    with use_sanitizer(False):
        yield


@pytest.fixture
def code():
    return get_code("wl32_r08")


def _words(code, batch=3):
    """All-zero words are valid codewords for every registry code."""
    return jnp.zeros((batch, code.n), jnp.int32)


def _layer(code_name="wl32_r08", *, batch=1, hkv=1, dh=8,
           page_tokens=4, n_pages=1, hot=2):
    pkv = ProtectedKVConfig(code_name=code_name, page_tokens=page_tokens,
                            fused=True)
    layer = ProtectedKVLayer(pkv, batch, hkv, dh)
    t = n_pages * page_tokens + hot
    k = jax.random.normal(jax.random.PRNGKey(0), (batch, t, hkv, dh),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(1), (batch, t, hkv, dh),
                          jnp.bfloat16)
    layer.append(k, v)
    assert layer.hot_len == hot
    return layer


def _q(layer, seed=7):
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (layer.batch, 1, 2 * layer.hkv, layer.dh),
                             jnp.bfloat16)


# ---------------------------------------------------------------------------
# ambient
# ---------------------------------------------------------------------------


def test_default_off_and_context_restores():
    assert not sanitizer_enabled()
    with use_sanitizer():
        assert sanitizer_enabled()
        with use_sanitizer(False):
            assert not sanitizer_enabled()
        assert sanitizer_enabled()
    assert not sanitizer_enabled()


def test_restores_on_exception():
    with pytest.raises(RuntimeError):
        with use_sanitizer():
            raise RuntimeError("boom")
    assert not sanitizer_enabled()


# ---------------------------------------------------------------------------
# injected out-of-range GF symbol: silent without, raises with
# ---------------------------------------------------------------------------


def test_scan_syndromes_out_of_range_symbol(code):
    assert code.n * (code.p - 1) ** 2 < 2 ** 31   # int32 accumulator bound
    y = _words(code).at[0, 0].set(code.p + 3)
    ht = jnp.asarray(code.H.T, jnp.int32)

    flags = ops.scan_syndromes(y, ht, code.p)      # silent: just a flag bit
    assert flags.shape == (3,)

    with use_sanitizer():
        with pytest.raises(SanitizerError, match="GF symbol"):
            ops.scan_syndromes(y, ht, code.p)
        ops.scan_syndromes(_words(code), ht, code.p)   # clean words pass


def test_decode_tolerates_drifted_levels(code):
    """Received words are raw arithmetic levels — drifting outside [0, p)
    is the MLC failure model, not a contract violation. The sanitizer
    checks what the decoder *produces* (symbols in-alphabet, finite LLV
    totals), so a drifted input must decode cleanly under it."""
    y = _words(code).at[1, 2].set(code.p)          # drifted one level up

    with use_sanitizer():
        y_corr, res = decode_integers(code, y, n_iters=4)
    sym = np.asarray(res.symbols)
    assert ((sym >= 0) & (sym < code.p)).all()
    assert np.isfinite(np.asarray(res.llv_totals)).all()


def test_gf_matmul_out_of_range_symbol():
    p = 5
    assert 8 * (p - 1) ** 2 < 2 ** 31             # int32 accumulator bound
    a = jnp.zeros((4, 8), jnp.int32).at[0, 0].set(p + 2)
    b = jnp.zeros((8, 4), jnp.int32)

    out = ops.gf_matmul(a, b, p)                   # silent: wraps mod p
    assert out.shape == (4, 4)

    with use_sanitizer():
        with pytest.raises(SanitizerError, match="gf_matmul lhs"):
            ops.gf_matmul(a, b, p)
        ops.gf_matmul(jnp.zeros((4, 8), jnp.int32), b, p)


# ---------------------------------------------------------------------------
# NaN attention accumulator: silent NaN output without, raises with
# ---------------------------------------------------------------------------


def test_attend_nan_accumulator_caught():
    layer = _layer()
    # Poison a hot token: the NaN flows through the online-softmax
    # m/l/acc recurrence and lands in the output without any exception.
    layer.hot_k = layer.hot_k.at[0, 0].set(jnp.nan)
    q = _q(layer)

    out = np.asarray(layer.attend(q), np.float32)
    assert np.isnan(out).any(), "expected silent NaN propagation"

    with use_sanitizer():
        with pytest.raises(SanitizerError, match="attend_protected"):
            layer.attend(q)


def test_attend_nan_query_caught():
    layer = _layer()
    q = _q(layer).at[0, 0, 0, 0].set(jnp.nan)

    layer.attend(q)                                # silent

    with use_sanitizer():
        with pytest.raises(SanitizerError, match="query"):
            layer.attend(q)


def test_attend_clean_passes_under_sanitizer():
    layer = _layer()
    q = _q(layer)
    ref = np.asarray(layer.attend(q), np.float32)
    with use_sanitizer():
        out = np.asarray(layer.attend(q), np.float32)
    np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------------------
# quantization scales
# ---------------------------------------------------------------------------


def test_quant_scales_checks():
    with use_sanitizer():
        check_quant_scales(jnp.asarray([0.0, 1.5, 2.0]))   # zero = padded page
        with pytest.raises(SanitizerError, match="scale"):
            check_quant_scales(jnp.asarray([1.0, -0.5]))
        with pytest.raises(SanitizerError):
            check_quant_scales(jnp.asarray([1.0, jnp.inf]))


# ---------------------------------------------------------------------------
# check primitives: disabled/no-op/skip semantics
# ---------------------------------------------------------------------------


def test_checks_are_noops_when_disabled():
    check_gf_symbols(jnp.asarray([99]), 5)
    check_finite(jnp.asarray([jnp.nan]))
    check_quant_scales(jnp.asarray([-1.0]))


def test_check_finite_ignores_integer_arrays():
    with use_sanitizer():
        check_finite(jnp.asarray([1, 2, 3], jnp.int32))


def test_checks_skip_empty_arrays():
    with use_sanitizer():
        check_gf_symbols(jnp.zeros((0, 4), jnp.int32), 5)
        check_finite(jnp.zeros((0,), jnp.float32))


def test_checks_skip_tracers_under_jit(code):
    """Under an enclosing jit the operands are tracers whose checkify error
    can't be thrown host-side — the sanitizer steps aside instead of
    breaking compiled pipelines (same convention as the obs feed)."""
    assert code.n * (code.p - 1) ** 2 < 2 ** 31   # int32 accumulator bound
    ht = jnp.asarray(code.H.T, jnp.int32)

    @jax.jit
    def scan(y):
        return ops.scan_syndromes(y, ht, code.p)

    y_bad = _words(code).at[0, 0].set(code.p + 3)
    with use_sanitizer():
        flags = scan(y_bad)
    assert flags.shape == (3,)
