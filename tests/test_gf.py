"""GF(p) arithmetic properties (hypothesis) + linear algebra mod p."""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import gf

PRIMES = [2, 3, 5, 7, 11]


@given(st.sampled_from(PRIMES), st.integers(-100, 100), st.integers(-100, 100),
       st.integers(-100, 100))
@settings(max_examples=60, deadline=None)
def test_field_axioms(p, a, b, c):
    add, mul = gf.gf_add, gf.gf_mul
    assert add(a, b, p) == add(b, a, p)
    assert mul(a, b, p) == mul(b, a, p)
    assert add(add(a, b, p), c, p) == add(a, add(b, c, p), p)
    assert mul(mul(a, b, p), c, p) == mul(a, mul(b, c, p), p)
    assert mul(a, add(b, c, p), p) == add(mul(a, b, p), mul(a, c, p), p)
    assert add(a, gf.gf_neg(a, p), p) == 0


@given(st.sampled_from(PRIMES), st.integers(1, 200))
@settings(max_examples=60, deadline=None)
def test_inverse(p, a):
    if a % p == 0:
        with pytest.raises(ZeroDivisionError):
            gf.gf_inv(a, p)
    else:
        assert gf.gf_mul(a % p, gf.gf_inv(a, p), p) == 1


@pytest.mark.parametrize("p", [2, 3, 5, 7])
def test_tables(p):
    mt = gf.mul_table(p)
    assert mt.shape == (p, p)
    assert (mt == mt.T).all()
    inv = gf.inv_table(p)
    for a in range(1, p):
        assert (a * inv[a]) % p == 1


@pytest.mark.parametrize("p", [2, 3, 5])
def test_rref_rank_inverse(rng, p):
    for _ in range(5):
        n = int(rng.integers(2, 8))
        m = rng.integers(0, p, (n, n))
        r = gf.gf_rank(m, p)
        assert 0 <= r <= n
        if r == n:
            inv = gf.gf_mat_inv(m, p)
            assert (gf.gf_matmul_np(m, inv, p) == np.eye(n)).all()


def test_centered_lift():
    assert [int(gf.centered_lift(np.int64(k), 3)) for k in range(3)] == [0, 1, -1]
    out = gf.centered_lift(np.arange(5), 5)
    assert out.tolist() == [0, 1, 2, -2, -1]


def test_is_prime():
    assert [n for n in range(2, 20) if gf.is_prime(n)] == [2, 3, 5, 7, 11, 13,
                                                           17, 19]
