"""Shared protected page pool: allocator, refcounts/aliasing, copy-on-write,
exhaustion, and scrub attribution (`repro.memory.pool`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_code
from repro.memory import (PoolExhausted, PooledStore, ProtectedPagePool,
                          asymmetric_adjacent)
from repro.memory.paged import PagedProtectedStore

CODE = "wl160_r08"


def _pool(capacity=8, page_words=6, **kw):
    return ProtectedPagePool(CODE, page_words=page_words,
                             capacity_pages=capacity, n_iters=8, **kw)


def _words(rng, m, k=None, p=None):
    code = get_code(CODE)
    return jnp.asarray(rng.integers(0, p or code.p, (m, k or code.k)),
                       jnp.int32)


# -- allocator / refcount ----------------------------------------------------


def test_alloc_free_cycle(rng):
    pool = _pool(capacity=3)
    a, b = pool.alloc("t0"), pool.alloc("t1")
    assert pool.n_allocated == 2 and pool.available == 1
    assert pool.owner(a) == "t0" and pool.refcount(b) == 1
    pool.free(a)
    assert pool.available == 2
    with pytest.raises(ValueError):
        pool.page(a)                       # freed page is inaccessible
    c = pool.alloc("t2")
    assert pool.n_allocated == 2
    assert int(jnp.sum(pool.page(c))) == 0  # realloc hands out a zeroed page
    pool.free(b), pool.free(c)
    assert pool.available == 3


def test_free_never_reclaims_live_refs(rng):
    """A page freed by one alias must stay live (and untouched) for the
    other holder — the free list never hands out a page with refs."""
    pool = _pool(capacity=2)
    pid = pool.alloc("a")
    marker = jnp.full((pool.page_words, pool.code.n), 2, jnp.int32)
    pool.set_page(pid, marker)
    pool.ref(pid)                          # second holder
    pool.free(pid)                         # first holder drops out
    assert pool.refcount(pid) == 1
    other = pool.alloc("b")                # must come from the free list
    assert other != pid
    assert np.array_equal(np.asarray(pool.page(pid)), np.asarray(marker))
    pool.free(pid)
    with pytest.raises(ValueError):
        pool.free(pid)                     # double free is a clean error


def test_exhaustion_is_clean():
    pool = _pool(capacity=2)
    pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()
    assert pool.n_allocated == 2           # failed alloc mutated nothing
    assert pool.available == 0


# -- pooled store: block tables, CoW, fork -----------------------------------


def test_pooled_store_matches_standalone(rng):
    """The pool-backed store is storage-indirection only: reads round-trip
    identically to a private PagedProtectedStore."""
    pool = _pool(capacity=8)
    st = PooledStore(pool, owner="t0")
    u = _words(rng, 15)
    st.append_words(u)
    assert st.n_pages == 3 and pool.n_allocated == 3
    ref = PagedProtectedStore(CODE, page_words=pool.page_words, n_iters=8)
    ref.append_words(u)
    # identical codewords to a private store, and info columns round-trip
    assert np.array_equal(np.asarray(st.export_words()),
                          np.asarray(ref.export_words()))
    back = st.read_info(0, 15)
    assert np.array_equal(np.asarray(back), np.asarray(u))
    st.free()
    assert st.n_pages == 0 and pool.available == 8


def test_fork_aliases_then_cow(rng):
    pool = _pool(capacity=8)
    st = PooledStore(pool, owner="a")
    st.append_words(_words(rng, 12))       # 2 full pages
    clone = st.fork(owner="b")
    assert clone.block_table == st.block_table
    assert pool.n_allocated == 2           # aliased, nothing copied
    assert all(pool.refcount(pid) == 2 for pid in st.block_table)
    before = np.asarray(st.page(0)).copy()
    # writing through the clone copies; the original never sees it
    clone._pages[0] = jnp.zeros_like(clone.page(0))
    assert clone.block_table[0] != st.block_table[0]
    assert pool.n_allocated == 3
    assert pool.refcount(st.block_table[0]) == 1
    assert np.array_equal(np.asarray(st.page(0)), before)
    clone.free()
    assert pool.n_allocated == 2           # copy + alias refs returned
    st.free()
    assert pool.available == 8


def test_append_exhaustion_preserves_block_table(rng):
    pool = _pool(capacity=2)
    st = PooledStore(pool, owner="a")
    u = _words(rng, 12)
    st.append_words(u)                     # fills the pool (2 pages)
    table = list(st.block_table)
    n_words = st.n_words
    with pytest.raises(PoolExhausted):
        st.append_words(_words(rng, 7))    # needs a 3rd page
    # the failed append mutated neither the table, the count, nor the data
    assert st.block_table == table and st.n_words == n_words
    assert np.array_equal(np.asarray(st.read_info(0, 12)), np.asarray(u))
    st.free()


def test_pages_needed_counts_cow_tail(rng):
    pool = _pool(capacity=8)
    st = PooledStore(pool, owner="a")
    st.append_words(_words(rng, 8))        # 1 full + 1 partial page
    assert st.pages_needed(4) == 0         # fits in the tail page
    assert st.pages_needed(5) == 1
    clone = st.fork(owner="b")
    # the aliased partial tail must CoW before it can take more words
    assert clone.pages_needed(1) == 1
    assert clone.pages_needed(5) == 2
    clone.free(), st.free()


# -- injection + scrub attribution -------------------------------------------


def test_inject_scopes_to_owner_and_scrub_attributes(rng):
    pool = _pool(capacity=8, page_words=4)
    a = PooledStore(pool, owner="a")
    b = PooledStore(pool, owner="b")
    a.append_words(_words(rng, 8))
    b.append_words(_words(rng, 8))
    ch = asymmetric_adjacent(pool.code.p, 2e-3, 1e-3)
    changed = pool.inject(ch, key=0, owners=["a"])
    assert changed > 0
    clean_b = [np.asarray(pg).copy() for pg in b._iter_pages()]
    rep = pool.scrub(max_pages=pool.capacity_pages)
    assert rep["flagged_words"] > 0 and rep["repaired_words"] > 0
    assert set(rep["by_owner"]) == {"a"}   # only a's pages were dirty
    # b's storage was swept but untouched
    for got, want in zip(b._iter_pages(), clean_b, strict=True):
        assert np.array_equal(np.asarray(got), want)
    # repairs stick: a second sweep flags only what the first could not fix
    rep2 = pool.scrub(max_pages=pool.capacity_pages)
    assert rep2["flagged_words"] == (rep["flagged_words"]
                                     - rep["repaired_words"])
    assert pool.scrub_by_owner["a"]["repaired_words"] > 0
    a.free(), b.free()


def test_scrub_round_robin_budget(rng):
    pool = _pool(capacity=8, page_words=4)
    st = PooledStore(pool, owner="a")
    st.append_words(_words(rng, 24))       # 6 pages
    seen = set()
    for _ in range(3):
        pool.scrub(max_pages=2)
        seen.add(pool._scrub_cursor)
    assert len(seen) == 3                  # cursor advances across calls
    assert pool.stats.scrub_rounds == 3
    assert pool.stats.scrub_words == 6 * 4
    st.free()


def test_scrub_min_age_skips_hot_pages(rng):
    pool = _pool(capacity=4, page_words=4)
    st = PooledStore(pool, owner="a")
    st.append_words(_words(rng, 8))        # 2 pages
    pool.touch(st.block_table[0], 10)      # hot
    pool.touch(st.block_table[1], 0)       # cold
    rep = pool.scrub(now=11, min_age=5)
    assert rep["pages"] == 1
    st.free()
