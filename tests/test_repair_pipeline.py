"""Coalesced repair pipeline parity: the cross-page `RepairQueue` must be
bit-exact with the per-page baseline at every layer.

FBP is row-independent (per-codeword early-exit freeze), so batching flagged
rows across pages/stores/tenants and decoding them through power-of-two
bucketed executables must reproduce the per-page sweep exactly: same repaired
symbols, same fail masks, same per-owner accounting. These tests pin that
down for every registry code, at bucket boundaries, and on the zero-flag
fast path.
"""
import numpy as np
import pytest

from repro.core import CODE_REGISTRY, get_code, np_encode_words
from repro.kernels.backend import KernelPolicy
from repro.memory import (PagedProtectedStore, PooledStore,
                          ProtectedPagePool, RepairQueue, bucket_sizes)
from repro.memory.controller import MemoryController

SLOW_N = 512            # codes at/above this wordline get the slow marker


def _corrupted(code, rng, n_words, n_errs):
    """(n_words, n) int8 codewords with `n_errs` single-cell hits spread
    over distinct rows, plus the clean reference."""
    w = rng.integers(0, code.p, (n_words, code.k))
    enc = np_encode_words(w, code).astype(np.int8)
    bad = enc.copy()
    rows = rng.choice(n_words, size=min(n_errs, n_words), replace=False)
    cols = rng.integers(0, code.n, rows.size)
    bad[rows, cols] = (bad[rows, cols] + 1) % code.p
    return bad, enc


def _ctrl(**kw):
    return MemoryController(n_iters=10, **kw)


def _scrub_both(code, bad, *, page_words, chunk_size=64, policy=None):
    """Run baseline and coalesced controller sweeps on copies of `bad`;
    return (baseline_report, coalesced_report, baseline_enc, coalesced_enc)."""
    reports, storages = [], []
    for coalesce in (False, True):
        kw = {"policy": policy} if policy is not None else {}
        ctrl = _ctrl(chunk_size=chunk_size, **kw)
        store = {"x": type("S", (), {"enc": bad.copy()})()}
        rep = ctrl.scrub(code, store, page_words=page_words,
                         coalesce=coalesce)
        reports.append(rep)
        storages.append(store["x"].enc)
    return reports[0], reports[1], storages[0], storages[1]


def _assert_reports_match(rb, rc):
    for key in ("pages", "words_scanned", "flagged", "corrected",
                "uncorrectable"):
        assert rb[key] == rc[key], (key, rb[key], rc[key])
    assert rb["coalesced"] is False and rc["coalesced"] is True
    # per-page stats: identical modulo the timing-free keys
    assert len(rb["page_stats"]) == len(rc["page_stats"])
    for sb, sc in zip(rb["page_stats"], rc["page_stats"], strict=True):
        for key in ("words", "flagged", "corrected", "uncorrectable"):
            assert sb[key] == sc[key], (key, sb, sc)


# ---------------------------------------------------------------------------
# registry-wide controller parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "name",
    [pytest.param(n, marks=pytest.mark.slow)
     if CODE_REGISTRY[n][0] >= SLOW_N else n
     for n in sorted(CODE_REGISTRY)])
def test_controller_parity_all_registry_codes(name, rng):
    """Acceptance: coalesced+bucketed scrub is bit-exact with the per-page
    baseline on every registry code (GF(3)/GF(5)/GF(7)). Decode is
    deterministic but not guaranteed to converge on every single hit for
    the small codes — residual rows must be exactly the uncorrectable ones,
    identical on both paths."""
    code = get_code(name)
    bad, clean = _corrupted(code, rng, n_words=96, n_errs=23)
    rb, rc, enc_b, enc_c = _scrub_both(code, bad, page_words=16)
    _assert_reports_match(rb, rc)
    np.testing.assert_array_equal(enc_b, enc_c)
    assert rc["corrected"] + rc["uncorrectable"] == rc["flagged"] == 23
    resid = (enc_c != clean).any(axis=1)
    assert int(resid.sum()) == rc["uncorrectable"]   # repaired rows exact
    assert rc["drains"] >= 1 and rc["repair_dispatch_rows"] >= rc["flagged"]


def test_controller_parity_device_scan_route(rng):
    """The windowed device scan route (scan-ahead + one device_get per
    window) flags and repairs identically to the host route."""
    code = get_code("wl160_r08")
    bad, clean = _corrupted(code, rng, n_words=128, n_errs=31)
    rb, rc, enc_b, enc_c = _scrub_both(
        code, bad, page_words=16, policy=KernelPolicy("interpret"))
    assert rc["backend"] == "device" and rb["backend"] == "device"
    _assert_reports_match(rb, rc)
    np.testing.assert_array_equal(enc_b, enc_c)
    np.testing.assert_array_equal(enc_c, clean)


def test_controller_zero_flag_sweep(rng):
    """A clean sweep never builds a decode dispatch: zero drains with work,
    zero pad rows, and storage is untouched on both paths."""
    code = get_code("wl64_r08")
    w = rng.integers(0, code.p, (64, code.k))
    clean = np_encode_words(w, code).astype(np.int8)
    rb, rc, enc_b, enc_c = _scrub_both(code, clean, page_words=16)
    _assert_reports_match(rb, rc)
    assert rb["flagged"] == rc["flagged"] == 0
    assert rc["repair_dispatch_rows"] == 0 and rc["repair_pad_rows"] == 0
    np.testing.assert_array_equal(enc_b, clean)
    np.testing.assert_array_equal(enc_c, clean)


@pytest.mark.parametrize("n_errs", [7, 8, 9, 63, 64, 65])
def test_controller_parity_bucket_boundaries(n_errs, rng):
    """Flag counts straddling the min-bucket (8) and chunk-size (64)
    boundaries: padding rows are invisible in symbols and accounting."""
    code = get_code("wl160_r08")
    bad, clean = _corrupted(code, rng, n_words=130, n_errs=n_errs)
    rb, rc, enc_b, enc_c = _scrub_both(code, bad, page_words=13,
                                       chunk_size=64)
    _assert_reports_match(rb, rc)
    assert rb["flagged"] == n_errs
    np.testing.assert_array_equal(enc_b, enc_c)
    np.testing.assert_array_equal(enc_c, clean)


# ---------------------------------------------------------------------------
# paged store + pool parity
# ---------------------------------------------------------------------------


def test_paged_store_parity(rng):
    code = get_code("wl160_r08")
    bad, clean = _corrupted(code, rng, n_words=96, n_errs=17)
    stores = []
    for coalesce in (False, True):
        st = PagedProtectedStore(code, page_words=16)
        st.append_encoded(bad)
        rep = st.scrub(coalesce=coalesce)
        stores.append((st, rep))
    (st_b, rb), (st_c, rc) = stores
    for key in ("pages", "flagged_words", "repaired_words"):
        assert rb[key] == rc[key], (key, rb, rc)
    assert rc["coalesced"] and rc["drain"]["entries"] >= 1
    for i in range(st_b.n_pages):
        np.testing.assert_array_equal(np.asarray(st_b.page(i)),
                                      np.asarray(st_c.page(i)))
    np.testing.assert_array_equal(st_c.export_words(), clean)


def test_pool_parity_per_owner_attribution(rng):
    """Two tenants share one pool; the coalesced sweep must report the same
    per-owner flagged/repaired splits as the per-page baseline."""
    code = get_code("wl160_r08")
    bad, clean = _corrupted(code, rng, n_words=192, n_errs=29)

    def sweep(coalesce):
        pool = ProtectedPagePool(code, page_words=16, capacity_pages=16)
        s1 = PooledStore(pool, owner="t1")
        s2 = PooledStore(pool, owner="t2")
        s1.append_encoded(bad[:96])
        s2.append_encoded(bad[96:])
        rep = pool.scrub(coalesce=coalesce)
        return pool, s1, s2, rep

    _, a1, a2, ra = sweep(False)
    _, b1, b2, rb = sweep(True)
    for key in ("pages", "flagged_words", "repaired_words"):
        assert ra[key] == rb[key], (key, ra, rb)
    assert ra["by_owner"] == rb["by_owner"]
    assert set(rb["by_owner"]) == {"t1", "t2"}
    np.testing.assert_array_equal(a1.export_words(), b1.export_words())
    np.testing.assert_array_equal(a2.export_words(), b2.export_words())
    np.testing.assert_array_equal(
        np.concatenate([b1.export_words(), b2.export_words()]), clean)


def test_pool_prioritized_scrub_coalesced(rng):
    """prioritize=True (dirtiest-first order) under the coalesced path
    still repairs everything and keeps the cursor semantics."""
    code = get_code("wl160_r08")
    bad, clean = _corrupted(code, rng, n_words=96, n_errs=13)
    pool = ProtectedPagePool(code, page_words=16, capacity_pages=8)
    st = PooledStore(pool, owner="t")
    st.append_encoded(bad)
    pool.scrub(prioritize=True)                    # seed EWMA flag rates
    rep = pool.scrub(prioritize=True, coalesce=True)
    assert rep["flagged_words"] == 0               # first sweep repaired all
    np.testing.assert_array_equal(st.export_words(), clean)


# ---------------------------------------------------------------------------
# RepairQueue unit surface
# ---------------------------------------------------------------------------


def _fresh_queue(monkeypatch, code, **kw):
    """A RepairQueue with a private executable cache — pad/dispatch
    accounting assertions must not depend on buckets other tests warmed
    in the process-wide cache."""
    from repro.memory import repair
    monkeypatch.setattr(repair, "_DECODER_CACHE", {})
    return RepairQueue(code, **kw)


def test_bucket_sizes_and_bucket_for():
    assert bucket_sizes(256) == [8, 16, 32, 64, 128, 256]
    assert bucket_sizes(64, min_bucket=16) == [16, 32, 64]
    assert bucket_sizes(6) == [6]                  # tiny chunk: single bucket
    q = RepairQueue(get_code("wl40_r08"), chunk_size=64)
    assert q.bucket_for(1) == 8 and q.bucket_for(8) == 8
    assert q.bucket_for(9) == 16 and q.bucket_for(64) == 64
    assert q.bucket_for(63) == 64


def test_dispatch_size_prefers_warm_buckets(monkeypatch):
    """A drain pads up to an already-built executable rather than building
    its ideal (smaller) bucket; the exact size always wins once built."""
    q = _fresh_queue(monkeypatch, get_code("wl40_r08"), chunk_size=64)
    assert q._dispatch_size(3) == 8                # cold: ideal bucket
    q._decoder(16)
    assert q._dispatch_size(3) == 16               # warm 16 absorbs 3 rows
    assert q._dispatch_size(16) == 16
    assert q._dispatch_size(17) == 32              # nothing warm fits: ideal
    q._decoder(8)
    assert q._dispatch_size(3) == 8                # exact size wins again


def test_repair_queue_drain_accounting(rng, monkeypatch):
    """Multi-entry drain: per-entry writebacks see their own slices, owners
    aggregate, pad accounting matches the bucket arithmetic."""
    code = get_code("wl160_r08")
    q = _fresh_queue(monkeypatch, code, chunk_size=64, n_iters=10)
    bad, clean = _corrupted(code, rng, n_words=11, n_errs=11)
    got = {}

    def wb(tag):
        def _wb(syms, ok):
            got[tag] = (syms.copy(), ok.copy())
        return _wb

    q.enqueue(bad[:4], wb("a"), owner="t1", provenance=("page", 0))
    q.enqueue(bad[4:], wb("b"), owner="t2", provenance=("page", 1))
    q.enqueue(np.zeros((0, code.n), np.int8), wb("c"))   # no-op enqueue
    assert len(q) == 2 and q.pending_words == 11
    rep = q.drain()
    assert len(q) == 0 and q.pending_words == 0
    assert rep["entries"] == 2 and rep["words"] == 11
    assert rep["repaired"] == 11 and rep["failed"] == 0
    # 11 rows -> one 16-row bucket: 5 pad rows
    assert rep["pad_rows"] == 5 and rep["dispatch_rows"] == 16
    assert rep["by_owner"] == {
        "t1": {"flagged_words": 4, "repaired_words": 4},
        "t2": {"flagged_words": 7, "repaired_words": 7}}
    np.testing.assert_array_equal(got["a"][0], clean[:4])
    np.testing.assert_array_equal(got["b"][0], clean[4:])
    assert got["a"][1].all() and got["b"][1].all()
    assert "c" not in got
    assert q.drains == 1 and q.total_rows == 11 and q.total_pad_rows == 5
    assert q.pad_waste == pytest.approx(5 / 16)
    # empty drain is a cheap no-op
    empty = q.drain()
    assert empty["entries"] == 0 and empty["words"] == 0
    assert q.drains == 1


def test_repair_queue_decode_batch_matches_unbucketed(rng, monkeypatch):
    """decode_batch through mixed bucket sizes equals one flat decode."""
    import jax.numpy as jnp

    from repro.core.decode import decode_integers
    code = get_code("wl160_r08")
    q = _fresh_queue(monkeypatch, code, chunk_size=16, min_bucket=8,
                     n_iters=10)
    bad, clean = _corrupted(code, rng, n_words=37, n_errs=37)
    syms, fail, _iters, pad_rows = q.decode_batch(bad)
    # 37 rows -> 16 + 16 + tail 5; the tail's ideal 8-bucket is cold but
    # the 16 executable is warm by then, so it absorbs the tail: 11 pads
    assert pad_rows == 11
    assert not fail.any()
    _yc, res = decode_integers(code, jnp.asarray(bad, jnp.int32),
                               n_iters=10, damping=q.damping,
                               llv_scale=q.llv_scale, llv_mode=q.llv_mode,
                               early_exit=True)
    np.testing.assert_array_equal(syms, np.asarray(res.symbols))
    np.testing.assert_array_equal(syms, clean)
