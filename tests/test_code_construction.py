"""NB-LDPC construction invariants: PEG graph, rank, systematic generator."""
import numpy as np
import pytest

from repro.core import gf
from repro.core.codes import REGISTRY as CODE_REGISTRY
from repro.core.codes import get_code
from repro.core.construction import build_code, peg_construct


@pytest.mark.parametrize("n,k,p", [(32, 26, 3), (64, 51, 3), (40, 32, 3),
                                   (48, 32, 5), (48, 32, 7)])
def test_generator_orthogonality(n, k, p):
    code = build_code(n, k, p=p)
    assert code.H.shape == (n - k, n)
    assert code.G.shape == (k, n)
    assert not gf.gf_matmul_np(code.G, code.H.T, p).any()          # Eq. 2
    assert gf.gf_rank(code.H, p) == n - k
    # systematic: G = [I | P]
    assert (code.G[:, :k] == np.eye(k)).all()


def test_peg_degree_distribution():
    n, c, dv = 60, 12, 3
    H = peg_construct(n, c, dv, 3, seed=1)
    assert ((H != 0).sum(axis=0) == dv).all()            # every VN degree dv
    cn_deg = (H != 0).sum(axis=1)
    assert cn_deg.max() - cn_deg.min() <= 2              # balanced CNs
    assert set(np.unique(H)) <= {0, 1, 2}


def test_edge_arrays_match_H():
    code = build_code(64, 51, p=3)
    for i in range(code.c):
        vns = code.cn_vns[i][code.cn_mask[i]]
        coefs = code.cn_coefs[i][code.cn_mask[i]]
        assert (code.H[i, vns] == coefs).all()
        assert (np.flatnonzero(code.H[i]) == np.sort(vns)).all()


def test_perm_tables_invert():
    code = build_code(64, 51, p=3)
    p = code.p
    # to_contrib then to_sym must round-trip the GF axis wherever mask is set
    for i in range(code.c):
        for j in range(code.dc_max):
            if not code.cn_mask[i, j]:
                continue
            fwd = code.perm_to_contrib[i, j]
            bwd = code.perm_to_sym[i, j]
            assert sorted(fwd.tolist()) == list(range(p))
            assert (fwd[bwd] == np.arange(p)).all()


def test_registry_all_buildable():
    for name, (n, k, p, _dv) in CODE_REGISTRY.items():
        if n > 512:
            continue                                   # keep test fast
        code = get_code(name)
        assert code.n == n and code.k == k and code.p == p
        assert abs(code.rate - k / n) < 1e-9


def test_headline_code_rate():
    # paper: >88% code rate at word length 1024
    n, k, p, dv = CODE_REGISTRY["wl1024_r088"]
    assert k / n > 0.88 and n == 1024
