"""Protected-memory subsystem: channel models, ProtectedMemoryArray,
controller policies, checkpoint integration, and the BER campaign engine."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import get_code
from repro.memory import (Compose, LevelTransition, PlusMinusOne,
                          ProtectedMemoryArray, ReadDisturb, RetentionDrift,
                          ScrubController, StuckAt, asymmetric_adjacent,
                          desymbolize_bytes, paper_schemes, run_campaign,
                          select_acceptance_row, symbolize_bytes,
                          uniform_flip)


# ---------------------------------------------------------------------------
# channels
# ---------------------------------------------------------------------------

LEVELS = jnp.asarray(np.random.default_rng(0).integers(0, 3, (32, 80)),
                     jnp.int32)


@pytest.mark.parametrize("ch", [
    uniform_flip(3, 0.05),
    asymmetric_adjacent(3, 0.04, 0.01),
    RetentionDrift(3, rate=1e-3, rest_level=0),
    ReadDisturb(3, per_read=1e-3),
    StuckAt(3, fraction=0.02, seed=11),
    Compose(asymmetric_adjacent(3, 0.02, 0.01), StuckAt(3, 0.01, seed=2)),
])
def test_channel_determinism_same_key_same_faults(ch):
    kw = dict(t=100.0, n_reads=50)
    a = np.asarray(ch.apply(jax.random.PRNGKey(42), LEVELS, **kw))
    b = np.asarray(ch.apply(jax.random.PRNGKey(42), LEVELS, **kw))
    assert (a == b).all()
    assert ((a >= 0) & (a < 3)).all()


def test_transition_matrix_row_stochasticity_validated():
    with pytest.raises(ValueError, match="sum to 1"):
        LevelTransition(np.array([[0.5, 0.4], [0.0, 1.0]]))
    with pytest.raises(ValueError, match="negative"):
        LevelTransition(np.array([[1.2, -0.2], [0.0, 1.0]]))
    with pytest.raises(ValueError, match="square"):
        LevelTransition(np.ones((2, 3)) / 3)
    # a valid matrix passes and reports its marginal error rate
    ch = uniform_flip(5, 0.1)
    assert ch.error_rate() == pytest.approx(0.1)


def test_retention_drift_grows_with_time_read_disturb_with_reads():
    drift = RetentionDrift(3, rate=1e-3, rest_level=0)
    assert drift.error_rate(t=0.0) == 0.0
    assert 0 < drift.error_rate(t=100.0) < drift.error_rate(t=2000.0)
    rd = ReadDisturb(3, per_read=1e-3)
    assert rd.error_rate(n_reads=0) == 0.0
    assert 0 < rd.error_rate(n_reads=10) < rd.error_rate(n_reads=1000)


def test_stuck_cells_are_persistent_across_keys():
    ch = StuckAt(3, fraction=0.05, stuck_level=1, seed=3)
    a = np.asarray(ch.apply(jax.random.PRNGKey(0), LEVELS))
    b = np.asarray(ch.apply(jax.random.PRNGKey(999), LEVELS))
    assert (a == b).all()                      # mask depends on seed, not key
    assert (a[a != np.asarray(LEVELS)] == 1).all()


def test_corrupt_exact_changes_exactly_m_cells():
    ch = asymmetric_adjacent(3, 0.04, 0.01)
    y = ch.corrupt_exact(jax.random.PRNGKey(5), LEVELS, 7)
    diffs = (np.asarray(y) != np.asarray(LEVELS)).sum(axis=1)
    assert (diffs == 7).all()


def test_plusminusone_is_integer_domain():
    ch = PlusMinusOne(0.5)
    y = jnp.zeros((8, 50), jnp.int32)
    out = np.asarray(ch.apply(jax.random.PRNGKey(0), y))
    assert set(np.unique(out)) <= {-1, 0, 1}
    exact = np.asarray(ch.corrupt_exact(jax.random.PRNGKey(1), y, 4))
    assert (np.abs(exact).sum(axis=1) == 4).all()


def test_compose_validates_alphabets():
    with pytest.raises(ValueError, match="mixed"):
        Compose(uniform_flip(3, 0.1), uniform_flip(5, 0.1))


# ---------------------------------------------------------------------------
# symbolization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [3, 5, 7])
def test_symbolize_roundtrip(p, rng):
    raw = rng.integers(0, 256, 513, np.uint8).tobytes()
    syms = symbolize_bytes(raw, p)
    assert syms.min() >= 0 and syms.max() < p
    assert desymbolize_bytes(syms, 513, p) == raw


# ---------------------------------------------------------------------------
# array + controller policies
# ---------------------------------------------------------------------------

def _array(ctrl, **kw):
    # note: `policy=` in **kw is the controller's KernelPolicy, distinct
    # from the controller-policy NAME passed positionally
    return ProtectedMemoryArray("wl80_r08", controller=ctrl,
                                chunk_size=64, **kw)


@pytest.mark.parametrize("policy", ["basic", "writeback", "scrub"])
def test_write_corrupt_read_roundtrip_exact(policy, rng):
    mem = _array(policy)
    if policy == "scrub":
        mem.controller.interval = 10 ** 9            # no auto-sweeps here
    t = rng.normal(size=(24, 12)).astype(np.float32)
    mem.write("t", t)
    mem.inject(asymmetric_adjacent(3, 3e-3, 1e-3), key=jax.random.PRNGKey(0))
    out = mem.read("t")
    assert np.array_equal(out, t)
    assert out.dtype == t.dtype
    detected_first = mem.stats.detected
    assert detected_first > 0
    assert mem.stats.corrected == detected_first
    assert mem.stats.uncorrectable == 0

    out2 = mem.read("t")                             # storage not re-corrupted
    assert np.array_equal(out2, t)
    redetected = mem.stats.detected - detected_first
    if policy == "basic":
        assert redetected == detected_first          # latent errors remain
        assert mem.stats.writebacks == 0
    else:
        assert redetected == 0                       # reads repaired storage
        assert mem.stats.writebacks == detected_first


def test_scrub_counters_and_repair(rng):
    mem = _array("writeback")
    a = rng.normal(size=(16, 8)).astype(np.float32)
    b = rng.integers(0, 1000, 64).astype(np.int32)
    mem.write("a", a)
    mem.write("b", b)
    total_words = mem.n_words()
    mem.inject(uniform_flip(3, 2e-3), key=jax.random.PRNGKey(1))

    report = mem.scrub()
    assert report["words_scanned"] == total_words
    assert report["corrected"] == report["flagged"] > 0
    assert report["uncorrectable"] == 0
    st = mem.stats
    assert st.scrub_rounds == 1
    assert st.scrub_words == total_words
    assert st.scrub_cells == total_words * mem.code.n
    assert st.scrub_corrected == report["corrected"]
    assert st.scrub_bandwidth_cells_per_s > 0

    # the sweep repaired storage: a clean re-scan flags nothing
    report2 = mem.scrub()
    assert report2["flagged"] == 0
    assert np.array_equal(mem.read("a"), a)
    assert np.array_equal(mem.read("b"), b)


def test_scrub_policy_autosweeps_on_interval(rng):
    mem = _array("scrub", use_sharded=False)
    mem.controller.interval = 2
    mem.write("x", rng.normal(size=(8, 4)).astype(np.float32))   # op 1
    mem.inject(uniform_flip(3, 5e-3), key=jax.random.PRNGKey(2))
    assert mem.stats.scrub_rounds == 0
    mem.read("x")                                                # op 2 -> sweep
    assert mem.stats.scrub_rounds == 1
    assert isinstance(mem.controller, ScrubController)


def test_uncorrectable_words_are_counted(rng):
    mem = _array("basic")
    mem.write("x", rng.normal(size=(32, 16)).astype(np.float32))
    # far beyond the code's strength: most words must fail to decode
    mem.inject(uniform_flip(3, 0.4), key=jax.random.PRNGKey(3))
    mem.read("x")
    assert mem.stats.uncorrectable > 0


def test_integer_channel_rejected_for_storage(rng):
    mem = _array("basic")
    mem.write("x", rng.normal(size=(4, 4)).astype(np.float32))
    with pytest.raises(ValueError, match="integer-domain"):
        mem.inject(PlusMinusOne(0.1))
    with pytest.raises(ValueError, match="alphabet"):
        mem.inject(uniform_flip(5, 0.1))


def test_import_export_stored_roundtrip(rng):
    src = _array("basic")
    t = rng.normal(size=(6, 6)).astype(np.float64)
    st = src.write("t", t)
    dst = _array("basic")
    dst.import_stored("t", st)
    assert np.array_equal(dst.read("t"), t)


def test_read_returns_writable_array(rng):
    """Regression: reads came back as read-only frombuffer views, so callers
    mutating a corrected read crashed."""
    mem = _array("basic")
    t = rng.normal(size=(6, 5)).astype(np.float32)
    mem.write("t", t)
    out = mem.read("t")
    assert out.flags.writeable
    out[0, 0] = 123.0                              # must not raise
    assert np.array_equal(mem.read("t"), t)        # storage untouched


# ---------------------------------------------------------------------------
# scrub engine: device scan backend + paged sweeps
# ---------------------------------------------------------------------------

from repro.core import CODE_REGISTRY, np_encode_words  # noqa: E402
from repro.kernels.backend import policy_from_scan_backend  # noqa: E402
from repro.memory.controller import MemoryController  # noqa: E402


def _corrupted_words(code, rng, n_words=24, n_clean=8):
    """(n_words, n) valid codewords with single-cell hits beyond n_clean."""
    w = rng.integers(0, code.p, (n_words, code.k))
    enc = np_encode_words(w, code).astype(np.int8)
    rows = np.arange(n_clean, n_words)
    cols = rng.integers(0, code.n, rows.size)
    enc[rows, cols] = (enc[rows, cols] + 1) % code.p
    return enc


@pytest.mark.parametrize("name", sorted(CODE_REGISTRY))
def test_device_scan_matches_host_scan_all_registry_codes(name, rng):
    """Acceptance: the fused Pallas scan's flagged mask is identical to the
    host BLAS scan on every registry code (GF(3)/GF(5)/GF(7))."""
    code = get_code(name)
    enc = _corrupted_words(code, rng)
    host = MemoryController(policy=policy_from_scan_backend("host"),
                            scan_block=16)
    dev = MemoryController(policy=policy_from_scan_backend("device"),
                           scan_block=16, use_sharded=False)
    mh = host._scan_syndromes(code, enc)
    md = dev._scan_syndromes(code, enc)
    np.testing.assert_array_equal(mh, md)
    assert not mh[:8].any() and mh[8:].all()       # scans also correct


def test_scan_backend_validated():
    # the legacy vocabulary lives on only in the converter; bad names still
    # fail loudly there, and the removed kwarg itself is a TypeError
    with pytest.raises(ValueError, match="scan_backend"):
        policy_from_scan_backend("gpu")
    with pytest.raises(TypeError, match="scan_backend"):
        MemoryController(scan_backend="host")  # noqa: RPL006  # asserts the kwarg removal


def test_page_words_validated(rng):
    """Regression: page_words <= 0 must raise eagerly, not silently sweep
    zero words (negative) or crash inside range() (zero)."""
    mem = _array("basic")
    mem.write("t", rng.normal(size=(4, 4)).astype(np.float32))
    for bad in (0, -1):
        with pytest.raises(ValueError, match="page_words"):
            mem.scrub(page_words=bad)


def test_page_stats_bounded(rng):
    """Sweeps past MAX_PAGE_STATS pages keep totals but cap the per-page
    list, so huge-archive sweeps stay one-page-resident."""
    from repro.memory import controller as ctl
    mem = _array("basic")
    mem.write("t", rng.normal(size=(600, 4)).astype(np.float32))
    n_words = mem.n_words()
    cap, ctl.MAX_PAGE_STATS = ctl.MAX_PAGE_STATS, 8
    try:
        rep = mem.scrub(page_words=2)
    finally:
        ctl.MAX_PAGE_STATS = cap
    assert rep["pages"] == -(-n_words // 2) > 8
    assert len(rep["page_stats"]) == 8
    assert rep["page_stats_truncated"]
    assert rep["words_scanned"] == n_words


def test_paged_scrub_matches_whole_array_scrub(rng):
    """Acceptance: paged sweeps give identical repair results to whole-array
    scrubs, and per-page stats sum to the sweep totals."""
    t = rng.normal(size=(128, 12)).astype(np.float32)
    repaired = {}
    for backend in ("host", "device"):
        for page_words in (None, 7):
            mem = _array("writeback", policy=policy_from_scan_backend(backend),
                         scan_block=32)
            mem.write("t", t)
            mem.inject(uniform_flip(3, 2e-3), key=jax.random.PRNGKey(4))
            rep = mem.scrub(page_words=page_words)
            assert rep["backend"] == backend
            assert rep["flagged"] == rep["corrected"] > 0
            if page_words is not None:
                assert rep["pages"] > 1
            for key in ("words", "flagged", "corrected", "uncorrectable"):
                total = rep["words_scanned"] if key == "words" else rep[key]
                assert sum(pg[key] for pg in rep["page_stats"]) == total
            assert np.array_equal(mem.read("t"), t)
            repaired[(backend, page_words)] = mem.stored("t").enc.copy()
    ref = repaired[("host", None)]
    assert all(np.array_equal(ref, enc) for enc in repaired.values())


def test_scrub_pages_accepts_external_page_iterator(rng):
    """The paged API scrubs any iterator of writable (b, n) pages — not just
    this array's store (the cold-storage-service surface)."""
    mem = _array("basic", policy=policy_from_scan_backend("host"))
    code = mem.code
    w = rng.integers(0, code.p, (40, code.k))
    want = np_encode_words(w, code).astype(np.int8)
    enc = want.copy()
    rows = np.arange(10, 40)
    cols = rng.integers(0, code.n, rows.size)
    enc[rows, cols] = (enc[rows, cols] + 1) % code.p
    pages = [enc[lo:lo + 9] for lo in range(0, 40, 9)]
    rep = mem.scrub_pages(iter(pages))
    assert rep["pages"] == 5
    assert rep["flagged"] == rep["corrected"] == 30
    assert np.array_equal(enc, want)               # repaired through views


def test_big_field_scan_falls_back_to_exact_int64(rng):
    """Regression: n*(p-1)^2 >= 2^24 used to AssertionError. The int64
    fallback must flag nothing on valid GF(4099) codewords — the float32
    path provably misflags every one of them at this field size."""
    from repro.core import build_code
    code = build_code(64, 48, p=4099, dv=4, seed=0)
    assert code.n * (code.p - 1) ** 2 >= 2 ** 24
    w = rng.integers(0, code.p, (32, code.k))
    enc = np_encode_words(w, code)
    f32 = (enc.astype(np.float32) @ code.H.T.astype(np.float32))
    assert np.any(f32.astype(np.int64) % code.p != 0)   # f32 IS inexact here
    host = MemoryController(policy=policy_from_scan_backend("host"),
                            use_sharded=False)
    assert not host._scan_syndromes(code, enc).any()
    enc[:, 0] = (enc[:, 0] + 1) % code.p
    assert host._scan_syndromes(code, enc).all()


def test_big_field_device_backend_routes_to_exact_host_scan(rng):
    """The fused kernel accumulates in int32; codes past its 2^31 bound must
    route the device backend to the exact host path instead of silently
    wrapping."""
    from repro.core import build_code
    code = build_code(48, 40, p=8191, dv=4, seed=0)
    assert code.n * (code.p - 1) ** 2 >= 2 ** 31
    w = rng.integers(0, code.p, (16, code.k))
    enc = np_encode_words(w, code)
    dev = MemoryController(policy=policy_from_scan_backend("device"),
                           use_sharded=False)
    assert dev._scan_route(code) == "host"          # routed past the kernel
    assert not dev._scan_syndromes(code, enc).any()
    # reports must label the backend that actually ran, not the config
    # (clean pages: GF(8191) decode would build a (p, p) conv table)
    assert dev.scrub_pages(code, iter([enc]))["backend"] == "host"
    enc[:, 3] = (enc[:, 3] + 1) % code.p
    assert dev._scan_syndromes(code, enc).all()


# ---------------------------------------------------------------------------
# checkpoint integration
# ---------------------------------------------------------------------------

def test_protected_checkpoint_survives_channel_faults(tmp_path, rng):
    from repro import checkpoint as ckpt
    tree = {"w": rng.normal(size=(32, 32)).astype(np.float32),
            "b": rng.normal(size=(32,)).astype(np.float32)}
    ckpt.save_checkpoint(str(tmp_path), 7, tree, protect=True)
    noise = Compose(asymmetric_adjacent(3, 2e-3, 1e-3),
                    StuckAt(3, 1e-4, seed=5))
    assert ckpt.inject_storage_faults(str(tmp_path), noise, key=0) > 0
    out, man = ckpt.restore_checkpoint(str(tmp_path), tree)
    assert all(np.array_equal(out[k], tree[k]) for k in tree)
    cs = man["correction_stats"]
    assert cs["corrected"] == cs["detected"] > 0
    assert cs["uncorrectable"] == 0


def test_protected_checkpoint_version_guard(tmp_path, rng):
    import json
    import os
    from repro import checkpoint as ckpt
    tree = {"w": rng.normal(size=(4, 4)).astype(np.float32)}
    d = ckpt.save_checkpoint(str(tmp_path), 1, tree, protect=True)
    mf = os.path.join(d, "manifest.json")
    with open(mf) as f:
        man = json.load(f)
    man["prot_version"] = 1
    with open(mf, "w") as f:
        json.dump(man, f)
    with pytest.raises(OSError, match="format"):
        ckpt.restore_checkpoint(str(tmp_path), tree)


# ---------------------------------------------------------------------------
# BER campaign engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_campaign_reproduces_paper_style_comparison():
    """Scaled-down acceptance check (the full wl1024 table is produced by
    benchmarks/bench_memory_mode.py): at a raw BER where Hamming SECDED has
    saturated, NB-LDPC still improves >= 10x over unprotected."""
    code = get_code("wl256_r08")
    out = run_campaign(paper_schemes(code), [2e-2, 1e-2, 1e-3, 1e-4],
                       trials=24, hamming_trials=512, seed=0)
    rows = out["rows"]
    by = {(r["scheme"], r["raw_ber"]): r for r in rows}
    # Hamming helps at low raw BER but saturates by 1e-2
    assert by[("hamming_secded", 1e-4)]["improvement"] > 50
    assert by[("hamming_secded", 1e-2)]["improvement"] < 3
    # the modulo checksum is detect-only in memory mode
    assert by[("modulo_parity", 1e-3)]["improvement"] == pytest.approx(1.0)
    acc = select_acceptance_row(rows)
    assert acc is not None
    assert acc["nbldpc_improvement"] >= 10.0


@pytest.mark.slow
def test_campaign_runs_level_domain_channels():
    """Any-channel support: the same engine runs an MLC level-transition
    channel instead of the ±1 integer channel."""
    from repro.memory import NBLDPCScheme
    code = get_code("wl80_r08")
    sch = NBLDPCScheme(code, asymmetric_adjacent(3, 0.7, 0.3), n_iters=8)
    r_word, r_info = sch.residuals_at(1, trials=16, seed=0)
    assert r_word == 0.0                        # single error always fixed
    r_word8, _ = sch.residuals_at(16, trials=16, seed=0)
    assert r_word8 > 0.0                        # way past the strength


@pytest.mark.slow
def test_ber_common_shim_and_info_residuals():
    from benchmarks.ber_common import ber_curve, ber_curves
    code = get_code("wl80_r08")
    curve, r = ber_curve(code, [1e-3, 1e-4], trials=16, max_errors=6)
    assert set(curve) == {1e-3, 1e-4}
    assert len(r) == 7
    curves, prof = ber_curves(code, [1e-3], trials=16, max_errors=6)
    assert curves["info"][1e-3] <= curves["word"][1e-3] * 1.5
    assert prof.n_info == code.k
