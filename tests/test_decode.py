"""Decoder behaviour: detection iff syndrome, t-error correction, max-plus
convolution properties (hypothesis), early exit, Manhattan-vs-Gaussian LLV."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import (decode_integers, decode_llv, encode_words, get_code,
                        init_llv, maxplus_conv, syndrome)
from repro.core.decode import _cn_fbp_jnp
from repro.core.llv import circular_distance, reinterpret


def _corrupt(rng, cw, n_err, mag=1):
    y = np.asarray(cw).copy()
    for b in range(y.shape[0]):
        idx = rng.choice(y.shape[1], n_err, replace=False)
        y[b, idx] += rng.choice([-mag, mag], n_err)
    return jnp.asarray(y)


@given(st.integers(0, 200))
@settings(max_examples=20, deadline=None)
def test_detection_iff_syndrome(seed):
    rng = np.random.default_rng(seed)
    code = get_code("wl40_r08")
    w = jnp.asarray(rng.integers(0, code.p, (4, code.k)))
    cw = encode_words(w, code)
    assert not np.asarray(syndrome(cw, code)).any()      # clean => zero (Eq.3)
    y = _corrupt(rng, cw, 1)
    assert np.asarray(syndrome(y % code.p, code)).any()  # single err detected


@given(st.integers(2, 7), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_maxplus_conv_commutes(p, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(3, p)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(3, p)).astype(np.float32))
    ab = maxplus_conv(a, b, p)
    ba = maxplus_conv(b, a, p)
    np.testing.assert_allclose(np.asarray(ab), np.asarray(ba), rtol=1e-6)


def test_maxplus_identity():
    p = 5
    e = jnp.full((1, p), -1e9).at[0, 0].set(0.0)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, p)), jnp.float32)
    np.testing.assert_allclose(np.asarray(maxplus_conv(x, e, p)),
                               np.asarray(x), rtol=1e-6)


@pytest.mark.parametrize("n_err,min_rate", [(1, 1.0), (2, 0.97), (3, 0.9)])
def test_correction_rate(rng, n_err, min_rate):
    code = get_code("wl160_r08")
    B = 64
    w = jnp.asarray(rng.integers(0, code.p, (B, code.k)))
    cw = encode_words(w, code)
    y = _corrupt(rng, cw, n_err)
    y_corr, res = decode_integers(code, y, n_iters=10, damping=0.3)
    ok = np.all(np.asarray(y_corr) == np.asarray(cw), axis=1).mean()
    assert ok >= min_rate, f"{n_err} errors: corrected {ok:.3f} < {min_rate}"


def test_eight_errors_wl1024():
    # paper headline: up to 8 errors in a 1024-symbol word
    rng = np.random.default_rng(1)
    code = get_code("wl1024_r08")
    B = 8
    w = jnp.asarray(rng.integers(0, code.p, (B, code.k)))
    cw = encode_words(w, code)
    y = _corrupt(rng, cw, 8)
    y_corr, _ = decode_integers(code, y, n_iters=12, damping=0.3)
    ok = np.all(np.asarray(y_corr) == np.asarray(cw), axis=1).mean()
    assert ok >= 0.7


def test_early_exit_matches_fixed(rng):
    code = get_code("wl40_r08")
    w = jnp.asarray(rng.integers(0, code.p, (8, code.k)))
    cw = encode_words(w, code)
    y = _corrupt(rng, cw, 1)
    a, ra = decode_integers(code, y, n_iters=8, early_exit=False)
    b, rb = decode_integers(code, y, n_iters=8, early_exit=True)
    assert (np.asarray(a) == np.asarray(b)).all()
    # iterations is per-codeword under the converged-mask early exit
    assert rb.iterations.shape == (8,)
    assert int(rb.iterations.max()) <= 8


def test_clean_word_zero_iterations_effect(rng):
    code = get_code("wl40_r08")
    w = jnp.asarray(rng.integers(0, code.p, (4, code.k)))
    cw = encode_words(w, code)
    y_corr, res = decode_integers(code, cw, n_iters=6)
    assert (np.asarray(y_corr) == np.asarray(cw)).all()
    assert not np.asarray(res.detect_fail).any()


def test_circular_distance_and_reinterpret():
    p = 3
    d = circular_distance(jnp.asarray([0.0, 1.0, 2.0, 3.0, -1.0]), p)
    assert d.shape == (5, 3)
    np.testing.assert_allclose(np.asarray(d[3]), [0, 1, 1])   # 3 ≡ 0 (mod 3)
    # reinterpret moves to the NEAREST representative of the decoded residue
    y = jnp.asarray([10, -4, 7])
    dec = jnp.asarray([1, 0, 1])
    out = reinterpret(y, dec, p)
    assert out.tolist() == [10, -3, 7]


def test_llv_modes_order():
    # Gaussian init should be at least as good as Manhattan (paper: the
    # simplification costs a little BER)
    rng = np.random.default_rng(3)
    code = get_code("wl160_r08")
    B = 48
    w = jnp.asarray(rng.integers(0, code.p, (B, code.k)))
    cw = encode_words(w, code)
    y = _corrupt(rng, cw, 4)
    ok = {}
    for mode in ("manhattan", "gaussian"):
        yc, _ = decode_integers(code, y, n_iters=10, llv_mode=mode,
                                damping=0.3)
        ok[mode] = np.all(np.asarray(yc) == np.asarray(cw), axis=1).mean()
    assert ok["gaussian"] >= ok["manhattan"] - 0.05


def test_fbp_eliminates_self_information():
    """External propagation must exclude the target slot's own message
    (paper §3.2.2 step 2)."""
    p = 3
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.normal(size=(1, 1, 4, p)).astype(np.float32))
    ext = _cn_fbp_jnp(m, p)
    m2 = m.at[0, 0, 2].set(jnp.asarray([100.0, -100.0, 0.0]))
    ext2 = _cn_fbp_jnp(m2, p)
    # slot 2's outgoing message is unchanged when slot 2's input changes
    np.testing.assert_allclose(np.asarray(ext[0, 0, 2]),
                               np.asarray(ext2[0, 0, 2]), rtol=1e-5)
