"""repro.analysis static pass: one positive + one negative + one noqa
fixture per RPL rule, engine/noqa semantics, CLI exit codes, and the
"repo is clean at head" regression."""
import json
import os
import pathlib

import pytest

from repro.analysis import RULES, run_file, run_paths
from repro.analysis.__main__ import main

REPO = pathlib.Path(__file__).resolve().parent.parent

# --------------------------------------------------------------------------
# fixtures: (relative path, source, 1-indexed line of the one violation)
# --------------------------------------------------------------------------

FIXTURES = {
    "RPL001": dict(
        path="fixture_rpl001.py",
        pos="""\
from jax.experimental import pallas as pl


def fwd(x, kernel):
    return pl.pallas_call(kernel, interpret=True)(x)
""",
        line=5,
        neg="""\
def fwd(x, run, interpret=None):
    return run(x, interpret=interpret)
""",
    ),
    "RPL002": dict(
        path="fixture_rpl002.py",
        pos="""\
from repro.kernels.ops import scan_syndromes


def scan(y, ht, p):
    return scan_syndromes(y, ht, p)
""",
        line=5,
        neg="""\
from repro.kernels.ops import scan_syndromes


def scan(y, ht, p):
    assert y.shape[1] * (p - 1) ** 2 < 2 ** 31
    return scan_syndromes(y, ht, p)
""",
    ),
    "RPL003": dict(
        path="fixture_rpl003.py",
        pos="""\
import functools
import time

import jax


@functools.partial(jax.jit, static_argnames=("n",))
def step(x, n):
    return x * time.time()
""",
        line=9,
        neg="""\
import functools
import time

import jax


def host():
    return time.time()


@functools.partial(jax.jit, static_argnames=("scale",))
def step(x, scale):
    return x * float(scale)
""",
    ),
    "RPL004": dict(
        path="fixture_rpl004.py",
        pos="""\
import jax


def run(xs, f):
    out = []
    for x in xs:
        out.append(jax.jit(f)(x))
    return out
""",
        line=7,
        neg="""\
import jax


class Decoder:
    def __init__(self):
        self._fn = None

    def get(self, f):
        if self._fn is None:
            self._fn = jax.jit(f)
        return self._fn
""",
    ),
    "RPL005": dict(
        # path-sensitive: only fires inside the hot-path packages
        path="repro/memory/fixture_rpl005.py",
        pos="""\
def read(reg, n):
    reg.counter("reads").inc(n)
""",
        line=2,
        neg="""\
def read(reg, n):
    if reg.enabled:
        reg.counter("reads").inc(n)


def scan(est, n):
    if not est.enabled:
        return
    est.observe_scan(n, 1)
""",
    ),
    "RPL006": dict(
        path="fixture_rpl006.py",
        pos="""\
from repro.memory.controller import MemoryController


def mk():
    return MemoryController(scan_backend="host")
""",
        line=5,
        neg="""\
from repro.memory.controller import MemoryController


def mk(other):
    other(backend="whatever")          # backend= only flags the removed ctors
    return MemoryController(policy="ref")
""",
    ),
    "RPL007": dict(
        # path-sensitive: only fires in memory/ and serving/
        path="repro/memory/fixture_rpl007.py",
        pos="""\
import numpy as np


def sweep(pages, fn):
    out = []
    for page in pages:
        out.append(np.asarray(fn(page)))
    return out
""",
        line=7,
        neg="""\
import jax


def sweep(pages, fn):
    launched = [fn(page) for page in pages]
    return jax.device_get(launched)
""",
    ),
}


def _write(tmp_path, rel, source):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(source)
    return str(path)


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_rule_positive(tmp_path, code):
    fx = FIXTURES[code]
    path = _write(tmp_path, fx["path"], fx["pos"])
    diags = run_file(path, select=[code])
    assert [d.code for d in diags] == [code], diags
    assert diags[0].line == fx["line"]
    assert diags[0].path.endswith(fx["path"])


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_rule_negative(tmp_path, code):
    fx = FIXTURES[code]
    path = _write(tmp_path, "neg_" + os.path.basename(fx["path"]),
                  fx["neg"]) if "/" not in fx["path"] else \
        _write(tmp_path, fx["path"].replace("fixture", "neg"), fx["neg"])
    assert run_file(path, select=[code]) == []


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_rule_noqa_suppression(tmp_path, code):
    fx = FIXTURES[code]
    lines = fx["pos"].splitlines()
    lines[fx["line"] - 1] += f"  # noqa: {code}  # fixture"
    path = _write(tmp_path, fx["path"], "\n".join(lines) + "\n")
    assert run_file(path, select=[code]) == []


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_seeded_fixture_fails_cli(tmp_path, capsys, code):
    """Acceptance: seeding any rule-violation fixture makes the CLI exit
    nonzero and report the correct RPL code and file:line."""
    fx = FIXTURES[code]
    _write(tmp_path, fx["path"], fx["pos"])
    rc = main([str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert code in out
    assert f"{fx['path']}:{fx['line']}:" in out.replace(os.sep, "/")


# --------------------------------------------------------------------------
# additional per-rule semantics beyond the canonical fixtures
# --------------------------------------------------------------------------


def test_rpl001_literal_default(tmp_path):
    src = """\
def kernel_entry(x, *, interpret=True):
    return x
"""
    path = _write(tmp_path, "f.py", src)
    diags = run_file(path, select=["RPL001"])
    assert len(diags) == 1 and diags[0].line == 1


def test_rpl001_backend_module_exempt(tmp_path):
    src = "POLICY = dict(interpret=True)\n"  # not even a call — clean anyway
    path = _write(tmp_path, "kernels/backend.py", src)
    assert run_file(path, select=["RPL001"]) == []


def test_rpl002_raw_pallas_entry(tmp_path):
    src = """\
from repro.kernels.gf_matmul import gf_matmul_pallas


def f(a, b):
    assert a.shape[1] * 6 ** 2 < 2 ** 31
    return gf_matmul_pallas(a, b, 7, bm=8, bn=8, bk=8)
"""
    path = _write(tmp_path, "f.py", src)
    diags = run_file(path, select=["RPL002"])
    # raw *_pallas entries are flagged even with a bound guard present
    assert len(diags) == 1 and "raw Pallas kernel" in diags[0].message


def test_rpl002_other_module_same_name_clean(tmp_path):
    src = """\
from mylib import scan_syndromes


def scan(y, ht, p):
    return scan_syndromes(y, ht, p)
"""
    path = _write(tmp_path, "f.py", src)
    assert run_file(path, select=["RPL002"]) == []


def test_rpl003_item_and_mutable_default(tmp_path):
    src = """\
import jax


@jax.jit
def step(x, acc=[]):
    acc.append(x.item())
    return x
"""
    path = _write(tmp_path, "f.py", src)
    msgs = [d.message for d in run_file(path, select=["RPL003"])]
    assert len(msgs) == 2
    assert any("mutable default" in m for m in msgs)
    assert any(".item()" in m for m in msgs)


def test_rpl003_float_of_traced_param(tmp_path):
    src = """\
import functools

import jax


@functools.partial(jax.jit, static_argnames=("n",))
def step(x, n):
    return float(x) + n
"""
    path = _write(tmp_path, "f.py", src)
    diags = run_file(path, select=["RPL003"])
    assert len(diags) == 1 and "float(x)" in diags[0].message


def test_rpl004_per_call_method_without_cache(tmp_path):
    src = """\
import jax


class Runner:
    def call(self, f, x):
        return jax.jit(f)(x)
"""
    path = _write(tmp_path, "f.py", src)
    diags = run_file(path, select=["RPL004"])
    assert diags and all(d.code == "RPL004" for d in diags)


def test_rpl005_early_out_guard(tmp_path):
    src = """\
def publish(reg, stats):
    if reg is None or not getattr(reg, "enabled", False):
        return
    reg.gauge("x").set(stats)
"""
    path = _write(tmp_path, "repro/core/f.py", src)
    assert run_file(path, select=["RPL005"]) == []


def test_rpl005_outside_hot_packages_clean(tmp_path):
    fx = FIXTURES["RPL005"]
    path = _write(tmp_path, "benchmarks/f.py", fx["pos"])
    assert run_file(path, select=["RPL005"]) == []


def test_rpl006_paged_dict_route(tmp_path):
    src = """\
def attend(apply, params, x, layer):
    return apply(params, x, kv_cache={"paged": layer})
"""
    path = _write(tmp_path, "f.py", src)
    diags = run_file(path, select=["RPL006"])
    assert len(diags) == 1 and "paged" in diags[0].message


def test_rpl007_outside_sync_packages_clean(tmp_path):
    fx = FIXTURES["RPL007"]
    path = _write(tmp_path, "repro/core/f.py", fx["pos"])
    assert run_file(path, select=["RPL007"]) == []


def test_rpl007_item_and_device_get_in_loop(tmp_path):
    src = """\
import jax


def drain(results, masks):
    total = 0
    for r in results:
        total += r.sum().item()
    while masks:
        jax.device_get(masks.pop())
    return total
"""
    path = _write(tmp_path, "repro/serving/f.py", src)
    diags = run_file(path, select=["RPL007"])
    assert [d.line for d in diags] == [7, 9]
    assert ".item()" in diags[0].message
    assert "jax.device_get" in diags[1].message


def test_rpl007_nested_def_in_loop_exempt(tmp_path):
    # a function *defined* in a loop body runs later, outside the loop
    src = """\
import numpy as np


def build(pages):
    thunks = []
    for page in pages:
        def pull(page=page):
            return np.asarray(page)
        thunks.append(pull)
    return thunks
"""
    path = _write(tmp_path, "repro/memory/f.py", src)
    assert run_file(path, select=["RPL007"]) == []


# --------------------------------------------------------------------------
# engine semantics
# --------------------------------------------------------------------------


def test_bare_noqa_suppresses_all_codes(tmp_path):
    fx = FIXTURES["RPL006"]
    lines = fx["pos"].splitlines()
    lines[fx["line"] - 1] += "  # noqa"
    path = _write(tmp_path, "f.py", "\n".join(lines) + "\n")
    assert run_file(path) == []


def test_noqa_other_code_does_not_suppress(tmp_path):
    fx = FIXTURES["RPL006"]
    lines = fx["pos"].splitlines()
    lines[fx["line"] - 1] += "  # noqa: RPL001"
    path = _write(tmp_path, "f.py", "\n".join(lines) + "\n")
    diags = run_file(path, select=["RPL006"])
    assert [d.code for d in diags] == ["RPL006"]


def test_syntax_error_reported_not_raised(tmp_path):
    path = _write(tmp_path, "f.py", "def broken(:\n")
    diags = run_file(path)
    assert [d.code for d in diags] == ["RPL000"]


def test_rule_registry_complete():
    assert sorted(RULES) == ["RPL001", "RPL002", "RPL003", "RPL004",
                             "RPL005", "RPL006", "RPL007"]
    for code, r in RULES.items():
        assert r.code == code and r.name and r.summary


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def test_cli_clean_dir_exit_zero(tmp_path, capsys):
    _write(tmp_path, "ok.py", "X = 1\n")
    assert main([str(tmp_path)]) == 0
    assert "0 diagnostics" in capsys.readouterr().out


def test_cli_json_output(tmp_path, capsys):
    fx = FIXTURES["RPL002"]
    _write(tmp_path, fx["path"], fx["pos"])
    rc = main([str(tmp_path), "--json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["files_scanned"] == 1
    [diag] = payload["diagnostics"]
    assert diag["code"] == "RPL002" and diag["line"] == fx["line"]


def test_cli_select_subsets_rules(tmp_path, capsys):
    fx = FIXTURES["RPL002"]
    _write(tmp_path, fx["path"], fx["pos"])
    assert main([str(tmp_path), "--select", "RPL001"]) == 0
    capsys.readouterr()


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in RULES:
        assert code in out


# --------------------------------------------------------------------------
# the pass runs clean on the repo at head (the CI analysis job's contract)
# --------------------------------------------------------------------------


def test_repo_is_clean_at_head():
    paths = [str(REPO / d) for d in ("src", "benchmarks", "tests",
                                     "examples")]
    diags, n_files = run_paths([p for p in paths if os.path.isdir(p)])
    assert n_files > 100
    assert diags == [], "\n".join(d.format() for d in diags)
