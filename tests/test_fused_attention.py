"""Fused GF-page paged attention: parity, policy dispatch, deprecations.

The central contract of the fused serving hot path
(`repro.kernels.ops.attend_protected`): attending directly over corrected
GF codeword pages must be BIT-IDENTICAL to the unfused streaming path
(`repro.nn.layers._attend_paged` over decoded/dequantized pages) — for
every registry code, on clean pages, on corrupted-then-corrected pages,
and at quantization edges. The Pallas kernel (interpret mode) keeps fp32
in VMEM (no bf16 round-trip between dequant and QK^T), so it is asserted
allclose at bf16 tolerance against the same reference.

Also covers the `KernelPolicy` redesign: `use_policy` overrides select the
right executable (no stale jit-cache hits), the removed legacy `backend=` /
`scan_backend=` kwargs fail loudly, and the `{"paged": ...}` dict-cache
form still warns through its deprecation window.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_code
from repro.core.codes import REGISTRY
from repro.kernels import KernelPolicy, current_policy, ops, use_policy
from repro.memory import asymmetric_adjacent
from repro.models.kv import ProtectedKVConfig, ProtectedKVLayer
from repro.nn.kv_source import KVSource
from repro.nn.layers import _attend_paged


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _mk_layer(code_name: str, *, batch=2, hkv=2, dh=8, page_tokens=4,
              fused=True, n_pages=2, hot=3, seed=0, edge=False):
    """A ProtectedKVLayer with `n_pages` frozen pages + `hot` hot tokens."""
    pkv = ProtectedKVConfig(code_name=code_name, page_tokens=page_tokens,
                            fused=fused)
    layer = ProtectedKVLayer(pkv, batch, hkv, dh)
    key = jax.random.PRNGKey(seed)
    t = n_pages * page_tokens + hot
    k = jax.random.normal(key, (batch, t, hkv, dh), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 1),
                          (batch, t, hkv, dh), jnp.bfloat16)
    if edge:
        # absmax saturation + exact-zero rows: the int8 clip/round edges
        k = k.at[:, 0].set(512.0)
        v = v.at[:, 0].set(-512.0)
        k = k.at[:, 1].set(0.0)
        v = v.at[:, 1].set(0.0)
    layer.append(k, v)
    assert layer.n_frozen == n_pages * page_tokens
    assert layer.hot_len == hot
    return layer


def _q(layer, seed=7):
    hq = layer.hkv * 2
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (layer.batch, 1, hq, layer.dh), jnp.bfloat16)


def _fused_vs_streaming(layer, softcap=0.0):
    q = _q(layer)
    fused = layer.attend(q, softcap)
    ref = _attend_paged(q, layer.pages(), softcap)
    return np.asarray(fused), np.asarray(ref)


# ---------------------------------------------------------------------------
# parity: every registry code x {clean, flagged-word, quantized-edge}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("code_name", sorted(REGISTRY))
def test_fused_bitexact_clean(code_name):
    layer = _mk_layer(code_name)
    fused, ref = _fused_vs_streaming(layer)
    assert np.array_equal(fused, ref), (
        f"fused != streaming on clean pages for {code_name}")


@pytest.mark.parametrize("code_name", sorted(REGISTRY))
def test_fused_bitexact_corrupted(code_name):
    """Inject correctable errors: the fused path consumes pages corrected
    by the scan-gated FBP upstream and must match the streaming corrected
    read bitwise — and corrections must be accounted."""
    code = get_code(code_name)
    layer = _mk_layer(code_name, seed=1)
    changed = layer.inject(asymmetric_adjacent(code.p, 0.002, 0.002), key=3)
    assert changed > 0
    fused, ref = _fused_vs_streaming(layer)
    assert np.array_equal(fused, ref), (
        f"fused != streaming on corrected pages for {code_name}")
    st = layer.stats()
    assert st["detected"] > 0


@pytest.mark.parametrize("code_name", ["wl40_r08", "wl160_r08"])
def test_fused_bitexact_quant_edges(code_name):
    """absmax-saturated and all-zero tokens hit the int8 clip/round edges;
    the in-kernel dequant must still replicate dequantize_tensor exactly."""
    layer = _mk_layer(code_name, edge=True)
    fused, ref = _fused_vs_streaming(layer)
    assert np.array_equal(fused, ref)


@pytest.mark.parametrize("softcap", [0.0, 30.0])
@pytest.mark.parametrize("hot", [0, 3])
def test_fused_bitexact_softcap_hot(softcap, hot):
    """Soft-capped logits and the empty-hot-page boundary (freeze-aligned
    token counts skip the hot update entirely)."""
    layer = _mk_layer("wl40_r08", hot=hot)
    q = _q(layer)
    fused = np.asarray(layer.attend(q, softcap))
    ref = np.asarray(_attend_paged(q, layer.pages(), softcap))
    assert np.array_equal(fused, ref)


def test_fused_no_frozen_pages_hot_only():
    """Before the first freeze there are zero GF pages; the fused path pads
    the page axis to the NP=1 bucket with no-op zero pages and must still
    match the streaming hot-only read bitwise."""
    layer = _mk_layer("wl40_r08", n_pages=0, hot=3)
    fused, ref = _fused_vs_streaming(layer)
    assert np.array_equal(fused, ref)


def test_fused_pallas_kernel_allclose():
    """The Pallas kernel (interpret mode on CPU) keeps fp32 in VMEM instead
    of the streaming path's bf16 page round-trips, so it is allclose — not
    bitwise — against the jnp oracle."""
    layer = _mk_layer("wl40_r08")
    q = _q(layer)
    with use_policy("ref"):
        ref = np.asarray(layer.attend(q, 0.0), np.float32)
    layer._gf_stack = None
    with use_policy("interpret"):
        kern = np.asarray(layer.attend(q, 0.0), np.float32)
    np.testing.assert_allclose(kern, ref, atol=2e-2, rtol=2e-2)


def test_fused_off_streams(monkeypatch):
    """fused=False must never touch attend_protected."""
    layer = _mk_layer("wl40_r08", fused=False)
    called = []
    monkeypatch.setattr(ops, "attend_protected",
                        lambda *a, **k: called.append(1))
    out = layer.attend(_q(layer), 0.0)
    assert not called and out.shape == (layer.batch, 1, 2 * layer.hkv,
                                        layer.dh)


def test_np_bucket():
    assert [ops.np_bucket(n) for n in (0, 1, 2, 3, 4, 5, 9)] == \
        [1, 1, 2, 4, 4, 8, 16]


# ---------------------------------------------------------------------------
# KernelPolicy: one policy object, jit-cache-correct overrides
# ---------------------------------------------------------------------------


def test_kernel_policy_resolution():
    assert KernelPolicy("ref").resolve() == "ref"
    assert KernelPolicy("interpret").resolve() == "interpret"
    on_tpu = jax.default_backend() == "tpu"
    assert KernelPolicy("auto").resolve() == (
        "compiled" if on_tpu else "ref")
    assert KernelPolicy("ref").interpret is True
    assert KernelPolicy("compiled").interpret is False
    with pytest.raises(ValueError, match="mode"):
        KernelPolicy("gpu")


def test_use_policy_override_selects_executable():
    """The regression the redesign exists for: resolving the policy inside
    a jitted wrapper caches the FIRST policy's trace; resolving outside
    must let an override switch executables. The ref and interpret modes
    agree numerically, so switching is observed via the dispatch seam."""
    assert current_policy().mode == "auto"
    with use_policy("interpret"):
        assert current_policy().mode == "interpret"
        with use_policy(KernelPolicy("ref")):
            assert current_policy().resolve() == "ref"
        assert current_policy().mode == "interpret"
    assert current_policy().mode == "auto"
    # numeric agreement across modes through the SAME public wrapper
    a = jnp.arange(12, dtype=jnp.int32).reshape(3, 4) % 5
    b = (jnp.arange(20, dtype=jnp.int32).reshape(4, 5) * 3) % 5
    with use_policy("ref"):
        r = ops.gf_matmul(a, b, 5)  # noqa: RPL002  # tiny fixed GF(5) case, far below the int32 bound
    with use_policy("interpret"):
        i = ops.gf_matmul(a, b, 5)  # noqa: RPL002  # tiny fixed GF(5) case, far below the int32 bound
    assert np.array_equal(np.asarray(r), np.asarray(i))


def test_flash_attention_honors_policy():
    """Regression for the hardcoded `interpret=True` default: flash_fwd now
    resolves through the policy (ref/auto off-TPU still interprets, so this
    asserts the resolution seam exists and runs)."""
    from repro.kernels.flash_attention import flash_fwd
    import inspect
    sig = inspect.signature(flash_fwd)
    assert sig.parameters["interpret"].default is None


# ---------------------------------------------------------------------------
# removed aliases: the one-release deprecation window is over; the old
# kwargs now fail loudly, and the converter functions carry the vocabulary
# ---------------------------------------------------------------------------


def test_store_backend_kwarg_removed():
    from repro.kernels.backend import policy_from_store_backend
    from repro.memory import PagedProtectedStore
    with pytest.raises(TypeError, match="backend"):
        PagedProtectedStore("wl40_r08", page_words=8, backend="ref")  # noqa: RPL006  # asserts the kwarg removal
    st = PagedProtectedStore("wl40_r08", page_words=8,
                             policy=policy_from_store_backend("ref"))
    assert st.policy.resolve() == "ref"


def test_pool_backend_kwarg_removed():
    from repro.kernels.backend import policy_from_store_backend
    from repro.memory.pool import ProtectedPagePool
    with pytest.raises(TypeError, match="backend"):
        ProtectedPagePool("wl40_r08", page_words=8, capacity_pages=4,
                          backend="ref")  # noqa: RPL006  # asserts the kwarg removal
    pool = ProtectedPagePool("wl40_r08", page_words=8, capacity_pages=4,
                             policy=policy_from_store_backend("ref"))
    assert pool.policy.resolve() == "ref"


def test_controller_scan_backend_kwarg_removed():
    from repro.kernels.backend import policy_from_scan_backend
    from repro.memory.controller import MemoryController
    with pytest.raises(TypeError, match="scan_backend"):
        MemoryController(scan_backend="host")  # noqa: RPL006  # asserts the kwarg removal
    ctl = MemoryController(policy=policy_from_scan_backend("host"))
    assert ctl.resolved_scan_backend() == "host"
    dev = MemoryController(policy=policy_from_scan_backend("device"))
    assert dev.resolved_scan_backend() == "device"


def test_paged_dict_cache_deprecated():
    """The {"paged": layer} routing warns and unwraps to KVSource
    dispatch with identical output."""
    from repro.configs import get_config
    from repro.nn.layers import attention_apply, init_attention
    from repro.configs.base import LayerSpec
    cfg = get_config("paper_pim").reduced(n_groups=1, d_model=32,
                                          n_heads=4, d_ff=64)
    params = init_attention(jax.random.PRNGKey(0), cfg)
    layer = _mk_layer("wl40_r08", hkv=cfg.n_kv_heads, dh=cfg.head_dim)
    x = jax.random.normal(jax.random.PRNGKey(2), (layer.batch, 1,
                                                  cfg.d_model), jnp.bfloat16)
    spec = LayerSpec(kind="attn")
    pos = jnp.asarray([[layer.n_tokens]] * layer.batch)
    with pytest.warns(DeprecationWarning, match="paged"):
        y_dict, _ = attention_apply(params, x, spec, cfg, positions=pos,
                                    kv_cache={"paged": layer})  # noqa: RPL006  # asserts the deprecation warning
    with warnings.catch_warnings():
        # the KVSource form must NOT warn
        warnings.simplefilter("error", DeprecationWarning)
        y_src, _ = attention_apply(params, x, spec, cfg, positions=pos,
                                   kv_cache=layer)
    assert np.asarray(y_dict).shape == np.asarray(y_src).shape


def test_kv_layer_is_kvsource():
    from repro.serving.engine import BatchedDenseKV, BatchedPagedKV
    assert issubclass(ProtectedKVLayer, KVSource)
    assert issubclass(BatchedPagedKV, KVSource)
    assert issubclass(BatchedDenseKV, KVSource)
    assert ProtectedKVLayer.kind == "protected"
    assert BatchedDenseKV.kind == "dense"
