"""Observability layer: metrics registry, span tracing, RAS estimators,
and the zero-cost-when-disabled contract of the instrumented hot paths."""
import json

import numpy as np
import pytest

from repro import obs
from repro.memory.channel import uniform_flip
from repro.obs import metrics as obs_metrics
from repro.obs import ras as obs_ras
from repro.obs import trace as obs_trace


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_instruments_and_snapshot_roundtrip():
    reg = obs.MetricsRegistry()
    reg.counter("reads", layer="controller", tenant="a").inc(3)
    reg.counter("reads", layer="controller", tenant="a").inc(2)
    reg.gauge("slots", layer="engine").set(7)
    h = reg.histogram("lat", layer="engine")
    for v in (0.001, 0.003, 0.2):
        h.observe(v)
    snap = json.loads(json.dumps(reg.snapshot()))   # JSON-stable
    assert obs.MetricsRegistry.value(snap, "reads", tenant="a",
                                     layer="controller") == 5.0
    # label order must not matter: same series either way
    assert reg.counter("reads", tenant="a", layer="controller").value == 5.0
    assert obs.MetricsRegistry.value(snap, "slots", layer="engine") == 7.0
    hist = snap["lat"]["series"][0]
    assert hist["count"] == 3 and hist["sum"] == pytest.approx(0.204)
    assert hist["buckets"]["+Inf"] == 3                 # cumulative
    assert obs.MetricsRegistry.value(snap, "nope") is None


def test_registry_kind_mismatch_rejected():
    reg = obs.MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError, match="registered as counter"):
        reg.gauge("x")


def test_registry_label_cardinality_bounded():
    reg = obs.MetricsRegistry(max_series=4)
    for i in range(4):
        reg.counter("hits", tenant=str(i)).inc()
    with pytest.warns(RuntimeWarning, match="max_series"):
        reg.counter("hits", tenant="overflowing").inc()
    reg.counter("hits", tenant="another").inc()         # warns only once
    snap = reg.snapshot()
    assert len(snap["hits"]["series"]) == 5             # 4 real + overflow
    assert obs.MetricsRegistry.value(snap, "hits", overflow="true") == 2.0


def test_registry_exporters():
    reg = obs.MetricsRegistry()
    reg.counter("mem_detected", code="gf3n32").inc(4)
    reg.histogram("step_s").observe(0.01)
    text = reg.to_prometheus()
    assert '# TYPE mem_detected_total counter' in text
    assert 'mem_detected_total{code="gf3n32"} 4.0' in text
    assert 'step_s_bucket{le="0.01"} 1' in text
    assert "step_s_count 1" in text


def test_registry_append_jsonl(tmp_path):
    reg = obs.MetricsRegistry()
    reg.counter("c").inc()
    path = tmp_path / "m.jsonl"
    reg.append_jsonl(str(path), meta={"bench": "unit"})
    reg.append_jsonl(str(path))
    lines = path.read_text().splitlines()
    assert len(lines) == 2
    rec = json.loads(lines[0])
    assert rec["bench"] == "unit"
    assert rec["metrics"]["c"]["series"][0]["value"] == 1.0


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------


def test_span_nesting_and_chrome_trace_export(tmp_path):
    tr = obs.Tracer()
    with obs.use_tracer(tr):
        with obs.span("outer", step=1) as sp:
            with obs.span("inner"):
                pass
            sp.set(tokens=4)
        tr.instant("mark", kind="preempt")
    path = tmp_path / "trace.json"
    doc = tr.to_chrome_trace(str(path))
    loaded = json.loads(path.read_text())
    assert loaded["traceEvents"] == doc["traceEvents"]

    inner, outer = tr.spans("inner")[0], tr.spans("outer")[0]
    # children close (and therefore record) before their parents; the
    # timestamps nest and depth rides in args
    assert outer["args"]["depth"] == 0 and inner["args"]["depth"] == 1
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert outer["args"] == {"step": 1, "tokens": 4, "depth": 0}
    marks = [e for e in tr.events() if e["ph"] == "i"]
    assert marks and marks[0]["name"] == "mark"


def test_tracer_bounds_event_count():
    tr = obs.Tracer(max_events=3)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    doc = tr.to_chrome_trace()
    assert len(doc["traceEvents"]) == 3
    assert doc["otherData"]["dropped_events"] == 2
    assert [e["name"] for e in doc["traceEvents"]] == ["s2", "s3", "s4"]


def test_span_disabled_is_shared_noop():
    assert obs_trace.current() is obs_trace.NULL_TRACER
    a = obs.span("anything", step=1)
    b = obs.span("else")
    assert a is b                       # one shared null span, no allocation
    with a as s:
        s.set(x=1)                      # no-op, no error


# ---------------------------------------------------------------------------
# RAS estimators
# ---------------------------------------------------------------------------


def test_ewma_converges_to_channel_flag_rate():
    """Feed scan observations drawn from a known LevelTransition channel;
    the flag-rate EWMA must converge to the closed-form expectation and the
    inverted raw BER to the channel's per-symbol error rate."""
    eps, n = 2e-3, 40
    ch = uniform_flip(3, eps)
    f_exp = obs_ras.expected_flag_rate(ch.T, n)
    est = obs.ErrorRateEstimator(alpha=0.05)
    rng = np.random.default_rng(0)
    words = 512
    for _ in range(400):
        flagged = int(rng.binomial(words, f_exp))
        est.observe_scan(flagged, words, n_symbols=n, region="bank0")
    r = est.region("bank0")
    assert r.flag_rate == pytest.approx(f_exp, rel=0.15)
    # eps is the per-symbol error prob (any wrong level), and raw_ber
    # inverts the word flag rate back to exactly that
    assert r.raw_ber() == pytest.approx(eps, rel=0.15)
    assert obs_ras.invert_flag_rate(f_exp, n) == pytest.approx(eps, rel=1e-6)


def test_estimator_stress_and_adaptive_interval():
    est = obs.ErrorRateEstimator(alpha=0.5, target_flag_rate=0.05)
    # clean region: interval stretches beyond nominal (capped by max_scale)
    for _ in range(8):
        est.observe_scan(0, 1024, region="cold")
    assert est.adaptive_interval(16, region="cold") > 16
    # hot region: flag rate far above target shrinks the interval
    for _ in range(8):
        est.observe_scan(512, 1024, region="hot")
        est.observe_decode([10, 10, 10], 10, detect_fail=[0, 0, 1],
                           region="hot")
    assert est.region("hot").stress == pytest.approx(1.0)
    assert est.adaptive_interval(16, region="hot") < 16
    assert est.hot_regions(1)[0][0] == "hot"
    # fleet-level pressure blends both; snapshot is JSON-stable
    json.dumps(est.snapshot())
    assert est.region("hot").residual_ber_proxy() > 0


def test_estimator_publish_to_registry():
    est = obs.ErrorRateEstimator(alpha=1.0)
    est.observe_scan(8, 64, n_symbols=32, region="t0")
    reg = obs.MetricsRegistry()
    est.publish(reg)
    snap = reg.snapshot()
    assert obs.MetricsRegistry.value(snap, "ras_flag_rate", layer="ras",
                                     region="t0") == pytest.approx(0.125)
    assert obs.MetricsRegistry.value(snap, "ras_raw_ber", layer="ras",
                                     region="t0") > 0


# ---------------------------------------------------------------------------
# disabled-path contract: telemetry off allocates nothing on hot paths
# ---------------------------------------------------------------------------


def test_disabled_hot_paths_allocate_no_instruments():
    """With no ambient registry/tracer/estimator, the instrumented read /
    scrub / decode paths must construct zero metric instruments and record
    zero events (the `.enabled` one-attribute-read contract)."""
    from repro.core import get_code, np_encode_words
    from repro.memory import PagedProtectedStore
    from repro.memory.controller import MemoryController

    assert obs_metrics.current() is obs_metrics.NULL_REGISTRY
    assert obs_ras.current() is obs_ras.NULL_ESTIMATOR

    rng = np.random.default_rng(0)
    code = get_code("wl32_r08")
    u = rng.integers(0, code.p, (12, code.k))
    st = PagedProtectedStore(code, page_words=8)
    st.append_words(u)
    ctl = MemoryController()
    enc = np_encode_words(u, code).astype(np.int8)

    before = obs.instrument_count()
    for i in range(st.n_pages):
        st.read_page_corrected(i)
    ctl.scrub_pages(code, iter([enc]))
    assert obs.instrument_count() == before
    # and the null sinks stayed empty
    assert obs_trace.current().events() == []
    assert obs_metrics.current().snapshot() == {}


def test_ambient_installers_nest_and_restore():
    reg, tr, est = (obs.MetricsRegistry(), obs.Tracer(),
                    obs.ErrorRateEstimator())
    with obs.use_metrics(reg), obs.use_tracer(tr), obs.use_estimator(est):
        assert obs_metrics.current() is reg
        assert obs_trace.current() is tr
        assert obs_ras.current() is est
        with obs.use_metrics() as inner:
            assert obs_metrics.current() is inner is not reg
        assert obs_metrics.current() is reg
    assert obs_metrics.current() is obs_metrics.NULL_REGISTRY
    assert obs_trace.current() is obs_trace.NULL_TRACER
    assert obs_ras.current() is obs_ras.NULL_ESTIMATOR


# ---------------------------------------------------------------------------
# ControllerStats dedup helpers (the engine's single banking path)
# ---------------------------------------------------------------------------


def test_controller_stats_merge_and_add_counts():
    from repro.memory.controller import ControllerStats
    a, b = ControllerStats(), ControllerStats()
    a.detected, a.corrected, a.words_read = 3, 2, 10
    b.detected, b.corrected, b.uncorrectable = 1, 1, 5
    out = ControllerStats().merge(a).merge(b)
    assert (out.detected, out.corrected, out.uncorrectable) == (4, 3, 5)
    assert out.words_read == 10
    assert a.correction_counts() == {"detected": 3, "corrected": 2,
                                     "uncorrectable": 0}
    # add_counts accepts both stats objects and plain dicts, and sums ONLY
    # the correction triple (scrub attribution has its own pool-side path)
    acc = dict.fromkeys(ControllerStats.CORRECTION_KEYS, 0)
    ControllerStats.add_counts(acc, a)
    ControllerStats.add_counts(acc, {"detected": 2, "scrub_flagged": 7})
    assert acc["detected"] == 5 and acc["corrected"] == 2
    assert "scrub_flagged" not in acc


def test_stats_publish_gauges_are_idempotent():
    from repro.memory.controller import ControllerStats
    s = ControllerStats()
    s.detected = 9
    reg = obs.MetricsRegistry()
    s.publish(reg, layer="pool")
    s.publish(reg, layer="pool")        # gauge-set, not counter-inc
    snap = reg.snapshot()
    assert obs.MetricsRegistry.value(snap, "controller_detected",
                                     layer="pool") == 9.0


# ---------------------------------------------------------------------------
# estimator-driven scrub prioritization (pool hot-page ordering)
# ---------------------------------------------------------------------------


def test_pool_prioritized_scrub_orders_by_flag_ewma():
    import jax
    import jax.numpy as jnp
    from repro.core import np_encode_words
    from repro.memory.pool import ProtectedPagePool

    pool = ProtectedPagePool("wl80_r08", page_words=8, capacity_pages=4)
    pids = [pool.alloc(owner=t) for t in ("a", "b", "c", "d")]
    rng = np.random.default_rng(1)
    code = pool.code
    for pid in pids:
        w = rng.integers(0, code.p, (8, code.k))
        pool.set_page(pid, jnp.asarray(np_encode_words(w, code), jnp.int32))
    # first sweep: every page scanned once, clean (EWMA baseline 0)
    pool.scrub()
    # exactly one wrong cell in every word of ONE page (always correctable)
    hot = pids[2]
    ch = uniform_flip(code.p, 0.02)
    pool.set_page(hot, ch.corrupt_exact(jax.random.PRNGKey(0),
                                        pool.page(hot), 1))
    est = obs.ErrorRateEstimator()
    with obs.use_estimator(est):
        rep = pool.scrub()                      # observes flags + repairs
    assert rep["flagged_words"] == rep["repaired_words"] == 8
    assert set(rep["by_owner"]) == {"c"}
    assert est.region("c").flag_rate == pytest.approx(1.0)
    # flag EWMA: 0 -> 0.3 * 1.0; the flagging page now ranks first
    assert pool.page_flag_rate(hot) == pytest.approx(0.3)
    assert pool.hot_pages(1) == [hot]
    # a 1-page prioritized sweep lands on the flagging page (now repaired,
    # so its EWMA decays by exactly 1 - flag_alpha), not the cursor's next
    rep1 = pool.scrub(max_pages=1, prioritize=True)
    assert rep1["pages"] == 1 and rep1["flagged_words"] == 0
    assert pool.page_flag_rate(hot) == pytest.approx(0.3 * 0.7)
    assert all(pool.page_flag_rate(p) == 0.0 for p in pids if p != hot)
