"""Property-based round-trip tests for the packing and quantization bridges
(`repro.memory.packing`, `repro.memory.paged`), via the `_hyp` shim: real
hypothesis when installed, a deterministic sample grid otherwise.

Every code in the registry is exercised (the bridges only depend on (p, k),
so the registry tuples are used directly — no parity matrices get built)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.codes import REGISTRY
from repro.memory.packing import (desymbolize_bytes, desymbolize_u8,
                                  digits_per_byte, symbolize_bytes,
                                  symbolize_u8)
from repro.memory.paged import (dequantize_tensor, quantize_tensor,
                                words_for_tensor)

ALPHABETS = sorted({p for (_n, _k, p, _dv) in REGISTRY.values()})
DTYPES = ["float32", "bfloat16", "float16"]


def _rand_shape(rng, max_rank=3, max_dim=7):
    rank = int(rng.integers(0, max_rank + 1))
    return tuple(int(rng.integers(1, max_dim + 1)) for _ in range(rank))


@pytest.mark.parametrize("p", ALPHABETS)
@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_symbolize_bytes_roundtrip(p, seed):
    rng = np.random.default_rng(seed)
    nbytes = int(rng.integers(0, 300))
    raw = rng.integers(0, 256, nbytes, dtype=np.uint8).tobytes()
    syms = symbolize_bytes(raw, p)
    assert syms.shape == (nbytes * digits_per_byte(p),)
    assert syms.min(initial=0) >= 0 and syms.max(initial=0) < p
    assert desymbolize_bytes(syms, nbytes, p) == raw


@pytest.mark.parametrize("p", ALPHABETS)
@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_symbolize_u8_roundtrip_and_host_interop(p, seed):
    rng = np.random.default_rng(seed)
    shape = _rand_shape(rng)
    vals = rng.integers(0, 256, shape)
    dev = symbolize_u8(jnp.asarray(vals), p)
    assert dev.shape == shape + (digits_per_byte(p),)
    assert np.array_equal(np.asarray(desymbolize_u8(dev, p)), vals)
    # device digits match the host pair byte-for-byte (checkpoint interop)
    host = symbolize_bytes(vals.reshape(-1).astype(np.uint8), p)
    assert np.array_equal(np.asarray(dev).reshape(-1), host)


@pytest.mark.parametrize("p", ALPHABETS)
@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_desymbolize_degrades_never_crashes(p, seed):
    rng = np.random.default_rng(seed)
    shape = _rand_shape(rng) + (digits_per_byte(p),)
    junk = rng.integers(-3, p + 4, shape)          # digits outside the field
    out = np.asarray(desymbolize_u8(jnp.asarray(junk), p))
    assert out.min(initial=0) >= 0 and out.max(initial=0) < 256


@pytest.mark.parametrize("code_name", sorted(REGISTRY))
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10**6),
       st.sampled_from(DTYPES))
def test_quantize_dequantize_roundtrip(code_name, seed, dtype):
    _n, k, p, _dv = REGISTRY[code_name]
    rng = np.random.default_rng(seed)
    shape = _rand_shape(rng)
    x = jnp.asarray(
        rng.standard_normal(shape) * 10.0 ** int(rng.integers(-2, 3)),
        dtype=dtype)
    words, meta = quantize_tensor(x, p, k)
    m = words_for_tensor(shape, p, k)
    assert words.shape == (m, k) and meta.n_words == m
    w = np.asarray(words)
    assert w.min(initial=0) >= 0 and w.max(initial=0) < p
    y = dequantize_tensor(words, meta, p)
    assert y.shape == x.shape and y.dtype == x.dtype
    # absmax-int8: elementwise error bounded by half a quantization step
    # (plus the output dtype's own rounding)
    err = np.abs(np.asarray(y, np.float32) - np.asarray(x, np.float32))
    step = float(meta.scale)
    tol = 0.5 * step + np.spacing(np.float32(step * 127), dtype=np.float32)
    if dtype != "float32":
        tol += np.abs(np.asarray(x, np.float32)).max(initial=0) * 2 ** -7
    assert err.max(initial=0.0) <= tol


@pytest.mark.parametrize("code_name", sorted(REGISTRY))
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_quantize_fixed_point(code_name, seed):
    """Requantizing a dequantized float32 tensor reproduces the exact same
    info words — the lattice is a fixed point, so freeze -> decode ->
    refreeze cycles (preemption replay) cannot drift."""
    _n, k, p, _dv = REGISTRY[code_name]
    rng = np.random.default_rng(seed)
    shape = _rand_shape(rng)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    words, meta = quantize_tensor(x, p, k)
    y = dequantize_tensor(words, meta, p)
    words2, meta2 = quantize_tensor(y, p, k)
    assert np.array_equal(np.asarray(words), np.asarray(words2))
    assert np.isclose(float(meta.scale), float(meta2.scale), rtol=1e-6)
