"""High-throughput decoder engine: vectorized max-plus conv vs seed reference,
converged-mask early exit, streaming decode, sharded decode (2-device CPU mesh
via subprocess), and the fbp_cn tile/pad regression."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import (decode_integers, decode_stream, encode_words,
                        get_code, maxplus_conv, maxplus_conv_ref)
from repro.core.decode import _cn_fbp_jnp, _cn_fbp_jnp_ref
from repro.distributed.sharding import data_mesh, decode_sharded


# ---------------------------------------------------------------------------
# vectorized max-plus conv == seed reference
# ---------------------------------------------------------------------------

@given(st.sampled_from([3, 5, 7]), st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_maxplus_conv_vectorized_matches_ref(p, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 5, size=rng.integers(1, 4))) + (p,)
    a = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    b = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(maxplus_conv(a, b, p)),
                                  np.asarray(maxplus_conv_ref(a, b, p)))


@given(st.sampled_from([3, 5, 7]), st.integers(1, 12), st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_cn_fbp_vectorized_matches_ref(p, dc, seed):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.normal(size=(2, 3, dc, p)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(_cn_fbp_jnp(m, p)),
                               np.asarray(_cn_fbp_jnp_ref(m, p)), rtol=1e-6)


# ---------------------------------------------------------------------------
# per-codeword early exit
# ---------------------------------------------------------------------------

def _corrupted_words(rng, code, B, n_err):
    w = jnp.asarray(rng.integers(0, code.p, (B, code.k)))
    cw = np.asarray(encode_words(w, code))
    y = cw.copy()
    for b in range(B):
        idx = rng.choice(y.shape[1], n_err, replace=False)
        y[b, idx] += rng.choice([-1, 1], n_err)
    return jnp.asarray(y), cw


def test_early_exit_equivalent_on_correctable_words(rng):
    code = get_code("wl160_r08")
    y, cw = _corrupted_words(rng, code, 16, 1)
    a, ra = decode_integers(code, y, n_iters=10, damping=0.3)
    b, rb = decode_integers(code, y, n_iters=10, damping=0.3, early_exit=True)
    assert (np.asarray(a) == cw).all()
    assert (np.asarray(b) == cw).all()
    assert not np.asarray(rb.detect_fail).any()
    # fixed path reports the full budget for every codeword
    assert (np.asarray(ra.iterations) == 10).all()
    # early exit reports per-codeword convergence iterations within budget
    assert rb.iterations.shape == (16,)
    assert (np.asarray(rb.iterations) <= 10).all()
    assert (np.asarray(rb.iterations) >= 1).all()


def test_early_exit_mixed_batch_freezes_converged(rng):
    """A hard straggler must not perturb already-converged codewords."""
    code = get_code("wl160_r08")
    y_easy, cw = _corrupted_words(rng, code, 4, 1)
    alone, r_alone = decode_integers(code, y_easy, n_iters=12, damping=0.3,
                                     early_exit=True)
    # mix in a heavily corrupted straggler that keeps the loop running
    y_hard = np.asarray(cw[:1]).copy()
    y_hard[0, ::3] += 1
    y_mix = jnp.concatenate([y_easy, jnp.asarray(y_hard)], axis=0)
    mixed, r_mix = decode_integers(code, y_mix, n_iters=12, damping=0.3,
                                   early_exit=True)
    # frozen outputs: easy words identical whether or not a straggler rides
    assert (np.asarray(mixed[:4]) == np.asarray(alone)).all()
    assert (np.asarray(r_mix.iterations[:4]) ==
            np.asarray(r_alone.iterations)).all()
    assert int(r_mix.iterations[4]) >= int(r_mix.iterations[:4].max())


# ---------------------------------------------------------------------------
# streaming decode
# ---------------------------------------------------------------------------

def test_decode_stream_matches_batch(rng):
    code = get_code("wl40_r08")
    y, cw = _corrupted_words(rng, code, 22, 1)     # ragged tail: 22 = 8+8+6
    full, _ = decode_integers(code, y, n_iters=8, damping=0.3,
                              early_exit=True)
    outs = list(decode_stream(code, y, chunk_size=8, n_iters=8, damping=0.3))
    got = np.concatenate([np.asarray(yc) for yc, _ in outs], axis=0)
    assert [yc.shape[0] for yc, _ in outs] == [8, 8, 6]
    assert (got == np.asarray(full)).all()
    for yc, res in outs:
        assert res.iterations.shape == (yc.shape[0],)
        assert res.detect_fail.shape == (yc.shape[0],)


def test_decode_stream_iterable_and_oversize(rng):
    code = get_code("wl40_r08")
    y, _ = _corrupted_words(rng, code, 6, 1)
    chunks = [y[:3], y[3:]]
    got = np.concatenate(
        [np.asarray(yc) for yc, _ in
         decode_stream(code, iter(chunks), chunk_size=4, n_iters=6,
                       damping=0.3)], axis=0)
    full, _ = decode_integers(code, y, n_iters=6, damping=0.3,
                              early_exit=True)
    assert (got == np.asarray(full)).all()
    with pytest.raises(ValueError):
        next(decode_stream(code, iter([y]), chunk_size=4))


# ---------------------------------------------------------------------------
# sharded decode
# ---------------------------------------------------------------------------

def test_decode_sharded_single_device_matches(rng):
    code = get_code("wl40_r08")
    y, cw = _corrupted_words(rng, code, 7, 1)      # odd B exercises padding
    base, rbase = decode_integers(code, y, n_iters=8, damping=0.3)
    mesh = data_mesh()
    out, res = decode_sharded(code, y, mesh=mesh, n_iters=8, damping=0.3)
    # sharded decode must be exactly the single-device computation
    assert (np.asarray(out) == np.asarray(base)).all()
    assert (np.asarray(res.detect_fail) == np.asarray(rbase.detect_fail)).all()
    assert res.detect_fail.shape == (7,)
    assert res.iterations.shape == (7,)
    # decode quality rides along: whatever the plain decoder corrected,
    # the sharded one corrected too
    assert ((np.asarray(out) == cw).all(axis=1) ==
            (np.asarray(base) == cw).all(axis=1)).all()


_SHARDED_2DEV_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax
    import jax.numpy as jnp
    assert len(jax.devices()) == 2, jax.devices()
    from repro.core import decode_integers, encode_words, get_code
    from repro.distributed.sharding import data_mesh, decode_sharded

    rng = np.random.default_rng(0)
    code = get_code("wl40_r08")
    w = jnp.asarray(rng.integers(0, code.p, (9, code.k)))
    cw = np.asarray(encode_words(w, code))
    y = cw.copy()
    for b in range(9):
        idx = rng.choice(code.n, 1)
        y[b, idx] += 1
    y = jnp.asarray(y)
    base, rbase = decode_integers(code, y, n_iters=8, damping=0.3,
                                  early_exit=True)
    out, res = decode_sharded(code, y, mesh=data_mesh(), n_iters=8,
                              damping=0.3, early_exit=True)
    assert (np.asarray(out) == np.asarray(base)).all()
    assert (np.asarray(res.iterations) == np.asarray(rbase.iterations)).all()
    assert res.iterations.shape == (9,)
    print("SHARDED-2DEV-OK")
""")


def test_decode_sharded_two_device_cpu_mesh():
    """decode_sharded over a 2-device CPU mesh == single-device decode.

    Runs in a subprocess because the host device count is fixed at jax
    import time (conftest must not set XLA_FLAGS globally).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARDED_2DEV_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "SHARDED-2DEV-OK" in proc.stdout


# ---------------------------------------------------------------------------
# fbp_cn tile/pad regression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,tile_n", [(3, 512), (7, 8), (12, 8), (100, 64),
                                      (70, 512)])
def test_fbp_cn_awkward_batches(rng, N, tile_n):
    """Tile must divide the padded batch for every (N, tile_n) combination."""
    from repro.kernels import ops, ref
    p, dc = 3, 5
    m = jnp.asarray(rng.normal(size=(N, dc, p)).astype(np.float32))
    out = ops.fbp_cn(m, p, tile_n=tile_n)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.fbp_cn_ref(m, p)),
                               rtol=1e-6, atol=1e-6)
