"""The beyond-paper performance paths: shard_map expert-parallel MoE
(subprocess with 8 placeholder devices) and budgeted detect-then-correct."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PIMConfig, ProtectionConfig, encode_weight_matrix,
                        get_code)
from repro.core.protected import (protected_pim_matmul,
                                  protected_pim_matmul_budgeted)


def test_budgeted_correction_matches_full(rng):
    code = get_code("wl160_r08")
    W = jnp.asarray(rng.integers(-1, 2, (32, 2 * code.k)), jnp.int32)
    We = encode_weight_matrix(W, code)
    x = jnp.asarray(rng.integers(-1, 2, (8, 32)), jnp.int32)
    exact = np.asarray(x @ W)
    cfgp = PIMConfig(output_error_rate=0.003)
    key = jax.random.PRNGKey(3)
    prot = ProtectionConfig(mode="correct", n_iters=10, damping=0.3)
    full = protected_pim_matmul(x, We, code, prot, cfgp, key=key)
    budg = protected_pim_matmul_budgeted(x, We, code, prot, cfgp, key=key,
                                         budget=16)
    raw = protected_pim_matmul(x, We, code, ProtectionConfig(mode="off"),
                               cfgp, key=key)
    ef = (np.asarray(full.y) != exact).mean()
    eb = (np.asarray(budg.y) != exact).mean()
    er = (np.asarray(raw.y) != exact).mean()
    assert er > 0
    assert eb <= ef + 1e-9
    assert eb < er / 2


def test_budgeted_overflow_flagged(rng):
    """More flagged words than budget -> uncorrected flags raised."""
    code = get_code("wl40_r08")
    W = jnp.asarray(rng.integers(-1, 2, (16, 8 * code.k)), jnp.int32)
    We = encode_weight_matrix(W, code)
    x = jnp.asarray(rng.integers(-1, 2, (8, 16)), jnp.int32)
    cfgp = PIMConfig(output_error_rate=0.08)       # floods the budget
    prot = ProtectionConfig(mode="correct", n_iters=4)
    res = protected_pim_matmul_budgeted(x, We, code, prot, cfgp,
                                        key=jax.random.PRNGKey(0), budget=2)
    assert bool(np.asarray(res.detected).any())
    assert bool(np.asarray(res.uncorrected).any())


def _budgeted_setup(rng, err_rate):
    code = get_code("wl40_r08")
    W = jnp.asarray(rng.integers(-1, 2, (16, 8 * code.k)), jnp.int32)
    We = encode_weight_matrix(W, code)
    x = jnp.asarray(rng.integers(-1, 2, (8, 16)), jnp.int32)
    prot = ProtectionConfig(mode="correct", n_iters=8, damping=0.3)
    cfgp = PIMConfig(output_error_rate=err_rate)
    return code, We, x, prot, cfgp


def test_budgeted_overflow_spares_corrected_words(rng):
    """Regression: on budget overflow, words the budget DID correct must not
    be reported uncorrected — only decode failures and the flagged words the
    budget never reached."""
    code, We, x, prot, cfgp = _budgeted_setup(rng, 0.02)
    res = protected_pim_matmul_budgeted(x, We, code, prot, cfgp,
                                        key=jax.random.PRNGKey(0), budget=2)
    det = np.asarray(res.detected)
    unc = np.asarray(res.uncorrected)
    assert det.sum() > 2                           # genuine overflow
    assert not (unc & ~det).any()                  # uncorrected ⊆ detected
    # at most `budget` words left the uncorrected set...
    assert unc.sum() >= det.sum() - 2
    # ...and at least one selected word was corrected and NOT blamed for
    # the overflow (the old accounting marked every detected word)
    assert unc.sum() < det.sum()


def test_budgeted_reports_per_word_decode_failures(rng):
    """Regression: per-word decoder failures within the budget were silently
    dropped. With a budget covering every flagged word, the budgeted path's
    uncorrected mask must equal the full path's detect_fail exactly."""
    for err_rate in (0.003, 0.25):                 # sparse and flooded
        code, We, x, prot, cfgp = _budgeted_setup(rng, err_rate)
        key = jax.random.PRNGKey(0)
        budg = protected_pim_matmul_budgeted(x, We, code, prot, cfgp,
                                             key=key, budget=64)
        full = protected_pim_matmul(x, We, code, prot, cfgp, key=key)
        np.testing.assert_array_equal(np.asarray(budg.detected),
                                      np.asarray(full.detected))
        np.testing.assert_array_equal(np.asarray(budg.uncorrected),
                                      np.asarray(full.uncorrected))
    # the flooded regime must actually contain decoder failures, or the
    # equality above proves nothing about failure accounting
    assert np.asarray(full.uncorrected).sum() > 0


_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.nn.moe import init_moe, moe_dense
from repro.nn.moe_shard import moe_shard_apply
from repro.distributed.sharding import use_rules

cfg = get_config("olmoe_1b_7b").reduced(n_experts=8)
cfg = dataclasses.replace(cfg, top_k=2, capacity_factor=8.0)
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
params = init_moe(key, cfg, 32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32)
                ).astype(jnp.bfloat16)
y_ref = moe_dense(params, x.reshape(-1, cfg.d_model), cfg).reshape(x.shape)
with use_rules(mesh, {"batch": "data", "expert": "model"}):
    with mesh:
        y = jax.jit(lambda p, x: moe_shard_apply(p, x, cfg))(params, x)
err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - y_ref.astype(jnp.float32))))
assert err < 0.1, err
def loss(p, x):
    return (moe_shard_apply(p, x, cfg)**2).sum().astype(jnp.float32)
with use_rules(mesh, {"batch": "data", "expert": "model"}):
    with mesh:
        g = jax.jit(jax.grad(loss))(params, x)
assert all(bool(jnp.isfinite(t).all()) for t in jax.tree.leaves(g))
print("SHARD_EP_OK", err)
"""


def test_shard_ep_moe_multidevice():
    """shard_map MoE vs dense oracle on 8 placeholder devices (subprocess so
    the main test process keeps its single real device)."""
    r = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                       capture_output=True, text=True, timeout=420)
    assert "SHARD_EP_OK" in r.stdout, r.stdout + r.stderr


def test_shard_ep_falls_back_without_mesh(rng):
    import dataclasses
    from repro.configs import get_config
    from repro.nn.moe import init_moe
    from repro.nn.moe_shard import moe_shard_apply
    cfg = get_config("olmoe_1b_7b").reduced(n_experts=8)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_moe(jax.random.PRNGKey(0), cfg, 32)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    y = moe_shard_apply(params, x.astype(jnp.bfloat16), cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(
        y.astype(jnp.float32)).all())
