"""Data pipeline, optimizers, checkpointing, fault tolerance, compression,
sharding rules, baselines."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro import checkpoint as ckpt
from repro.core.baselines import HammingSECDED, ModuloParity, SuccessiveCorrection
from repro.data import DataConfig, TokenPipeline
from repro.distributed.compression import dequantize, init_ef, quantize_ef
from repro.distributed.fault import RestartManager, StragglerWatchdog
from repro.distributed.sharding import resolve_spec, use_rules
from repro.optim import adafactor, adamw, clip_grads, warmup_cosine


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_determinism_and_resume():
    c = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    p = TokenPipeline(c)
    batches = [next(p) for _ in range(3)]
    q = TokenPipeline.restore(c, {"step": 1, "seed": 0})
    assert np.array_equal(next(q)["tokens"], batches[1]["tokens"])
    # labels are next-token shifted
    b = batches[0]
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_shards_disjoint_and_elastic():
    c = DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    a0 = next(TokenPipeline(c, 0, 2))["tokens"]
    a1 = next(TokenPipeline(c, 1, 2))["tokens"]
    assert not np.array_equal(a0, a1)
    # elastic: resharding to 4 shards still yields deterministic streams
    b0 = next(TokenPipeline(c, 0, 4))["tokens"]
    assert b0.shape == (2, 8)


def test_data_has_learnable_structure():
    c = DataConfig(vocab_size=64, seq_len=256, global_batch=2)
    toks = next(TokenPipeline(c))["tokens"]
    # Markov structure: bigram entropy < unigram entropy by a margin
    flat = toks.reshape(-1)
    uni = np.bincount(flat, minlength=64) / flat.size
    h_uni = -(uni[uni > 0] * np.log(uni[uni > 0])).sum()
    pairs = flat[:-1] * 64 + flat[1:]
    joint = np.bincount(pairs, minlength=64 * 64) / pairs.size
    h_joint = -(joint[joint > 0] * np.log(joint[joint > 0])).sum()
    h_cond = h_joint - h_uni
    assert h_cond < h_uni - 0.3


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make,thresh", [(lambda: adamw(5e-2), 0.05),
                                         (lambda: adafactor(5e-1), 0.05),
                                         (lambda: adafactor(3e-1, momentum=0.5),
                                          0.25)])
def test_optimizers_converge_quadratic(make, thresh):
    tx = make()
    params = {"w": jnp.ones((6, 3)), "b": jnp.zeros((3,))}
    target = jnp.asarray([1.0, -2.0, 0.5])

    def loss(p):
        return jnp.sum((jnp.ones((6,)) @ p["w"] + p["b"] - target) ** 2)

    state = tx.init(params)
    l0 = float(loss(params))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = tx.update(g, state, params)
    assert float(loss(params)) < thresh * l0


def test_grad_clipping():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, gn = clip_grads(g, 1.0)
    assert float(gn) == pytest.approx(200.0)
    norm = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert norm == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_schedule():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(100)) == pytest.approx(0.1, abs=1e-6)
    assert float(s(55)) < float(s(20))


def test_adafactor_memory_is_factored():
    tx = adafactor(1e-2)
    params = {"w": jnp.zeros((64, 32))}
    state = tx.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state["f"]))
    assert n_state == 64 + 32            # vs 2*64*32 for adamw


# ---------------------------------------------------------------------------
# checkpoint + fault tolerance
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_atomic_retention():
    with tempfile.TemporaryDirectory() as d:
        tree = {"p": np.arange(6, np.float32).reshape(2, 3) if False else
                np.arange(6, dtype=np.float32).reshape(2, 3),
                "n": {"s": np.int32(3) * np.ones(2, np.int32)}}
        for step in (10, 20, 30, 40):
            ckpt.save_checkpoint(d, step, tree, keep=2)
        names = sorted(os.listdir(d))
        assert names == ["step_00000030", "step_00000040"]
        out, man = ckpt.restore_checkpoint(d, tree)
        assert man["step"] == 40
        assert np.array_equal(out["p"], tree["p"])


def test_checkpoint_nb_ldpc_protection_corrects_bitflips():
    """The paper's memory mode protecting the framework's own storage.
    Storage rot is injected through the channel API (format-agnostic)."""
    from repro.memory import uniform_flip
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": np.linspace(-1, 1, 32, dtype=np.float32)}
        ckpt.save_checkpoint(d, 1, tree, protect=True)
        n = ckpt.inject_storage_faults(d, uniform_flip(3, 8e-3), key=0)
        assert n > 0                                 # fixed key: deterministic
        out, _ = ckpt.restore_checkpoint(d, tree)
        assert np.array_equal(out["w"], tree["w"])   # ECC fixed the flips


def test_restart_manager_recovers_from_crash():
    with tempfile.TemporaryDirectory() as d:
        mgr = RestartManager(d, save_every=1, max_restarts=2)
        calls = {"n": 0}

        def init_fn():
            return {"x": np.zeros(3, np.float32)}

        def loop(start, data_state):
            calls["n"] += 1
            state = {"x": np.full(3, start, np.float32)}
            for step in range(start, 5):
                state = {"x": state["x"] + 1}
                mgr.maybe_save(step, state, data_state={"step": step,
                                                        "seed": 0})
                if calls["n"] == 1 and step == 3:
                    raise RuntimeError("simulated node failure")
            return 5

        assert mgr.run(loop, init_fn) == 5
        assert calls["n"] == 2
        assert ckpt.latest_step(d) == 4


def test_straggler_watchdog_flags():
    import time
    dog = StragglerWatchdog(threshold=1.5)
    for i in range(3):
        dog.step_start(); time.sleep(0.01); dog.step_end(i)
    dog.step_start(); time.sleep(0.08); dog.step_end(3)
    assert len(dog.flagged) == 1 and dog.flagged[0][0] == 3


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@given(st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_ef_quantization_error_is_fed_back(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    ef = init_ef({"x": x})["x"]
    # repeated quantization of the SAME tensor: error feedback makes the
    # time-average converge to the true value
    acc = np.zeros(64)
    n = 40
    for _ in range(n):
        q, s, ef = quantize_ef(x, ef)
        acc += np.asarray(dequantize(q, s))
    np.testing.assert_allclose(acc / n, np.asarray(x), atol=2e-2)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_constrain_is_noop_without_mesh():
    from repro.distributed.sharding import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "batch", None) is x


def test_resolve_spec_with_rules():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    with use_rules(mesh, {"batch": "data", "d_ff": "model", "kv_seq": None}):
        assert resolve_spec(("batch", None, "d_ff")) == P("data", None, "model")
        assert resolve_spec(("kv_seq",)) == P(None)


# ---------------------------------------------------------------------------
# baseline ECCs (paper Table 2 comparators)
# ---------------------------------------------------------------------------

def test_hamming_secded_corrects_single_detects_double(rng):
    h = HammingSECDED()
    bits = rng.integers(0, 2, (20, 32))
    word = h.encode(bits)
    # single-bit error in every word -> corrected
    w1 = word.copy()
    for i in range(20):
        w1[i, rng.integers(0, w1.shape[1] - 1)] ^= 1
    dec, unc = h.decode(w1)
    assert (dec == bits).all() and not unc.any()
    # double-bit error -> flagged uncorrectable
    w2 = word.copy()
    w2[:, 3] ^= 1
    w2[:, 9] ^= 1
    _, unc2 = h.decode(w2)
    assert unc2.all()


def test_modulo_parity_detects(rng):
    mp = ModuloParity(q=3)
    W = jnp.asarray(rng.integers(-1, 2, (16, 8)), jnp.int32)
    We = mp.encode_weights(W)
    x = jnp.asarray(rng.integers(-1, 2, (4, 16)), jnp.int32)
    Y = (x @ We).astype(jnp.int32)
    assert not np.asarray(mp.detect(Y)).any()
    Yb = Y.at[1, 2].add(1)
    assert np.asarray(mp.detect(Yb)).any()


def test_successive_correction_fixes_up_to_budget(rng):
    sc = SuccessiveCorrection(max_rereads=3)
    W = jnp.asarray(rng.integers(-1, 2, (16, 10)), jnp.int32)
    x = jnp.asarray(rng.integers(-1, 2, (4, 16)), jnp.int32)
    Y = (x @ W).astype(jnp.int32)
    Yb = Y.at[0, 1].add(1).at[2, 5].add(-1)
    Yf, n = sc.correct(x, W, Yb)
    assert (np.asarray(Yf) == np.asarray(Y)).all()
    assert int(n) == 2
