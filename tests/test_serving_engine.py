"""Multi-tenant serving engine (`repro.serving.engine`): continuous
batching, bit-exactness across occupancy, mid-serving fault injection with
per-tenant attribution, preemption/readmission, background scrub."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import get_code
from repro.memory import (Compose, LevelTransition, PoolExhausted,
                          ProtectedPagePool, ReadDisturb,
                          asymmetric_adjacent)
from repro.memory.paged import words_for_tensor
from repro.models import ProtectedKVConfig, init_params
from repro.serving import ServingEngine

CODE = "wl160_r08"
PAGE_TOKENS = 8


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("paper_pim").reduced(n_groups=2, d_model=32,
                                          n_heads=2, d_ff=64, vocab=128)
    params = jax.tree.map(lambda t: t * 3.0,
                          init_params(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12) for _ in range(4)]
    return cfg, params, prompts


def _pool(cfg, capacity):
    code = get_code(CODE)
    wpu = words_for_tensor((1, PAGE_TOKENS, cfg.n_kv_heads, cfg.head_dim),
                           code.p, code.k)
    return ProtectedPagePool(code, page_words=wpu, capacity_pages=capacity,
                             n_iters=8)


def _engine(cfg, params, pool, **kw):
    pkv = ProtectedKVConfig(code_name=CODE, page_tokens=PAGE_TOKENS,
                            n_iters=8)
    kw.setdefault("max_active", 4)
    kw.setdefault("max_seq", 48)
    return ServingEngine(params, cfg, pkv=pkv, pool=pool, **kw)


def _serve(eng, prompts, gen=8):
    for t, p in enumerate(prompts):
        eng.submit(t, p, max_new=gen)
    return eng.run()


def _mixed_channel(p, eps):
    # level drift + read disturb, composed — the stress mix from the issue
    drift = asymmetric_adjacent(p, eps, eps / 2)
    return Compose(LevelTransition(drift.T), ReadDisturb(p, eps / 2))


def test_engine_smoke_and_pool_drains(tiny):
    cfg, params, prompts = tiny
    pool = _pool(cfg, 256)
    eng = _engine(cfg, params, pool)
    out = _serve(eng, prompts)
    assert sorted(out) == [0, 1, 2, 3]
    assert all(len(v) == 8 for v in out.values())
    st = eng.stats()
    assert st["done"] == 4 and st["active"] == 0 and st["waiting"] == 0
    # every retired slot returned its blocks to the shared free list
    assert pool.n_allocated == 0 and pool.available == 256


def test_single_vs_multi_tenant_bit_exact(tiny):
    cfg, params, prompts = tiny
    pool = _pool(cfg, 256)
    multi = _serve(_engine(cfg, params, pool), prompts)
    for t, p in enumerate(prompts):
        solo = _serve(_engine(cfg, params, pool), [p])
        assert solo[0] == multi[t], f"tenant {t} diverged under batching"


def test_injection_mid_serving_corrected_and_attributed(tiny):
    """Satellite stress: corrupt the shared pool mid-serving across 4
    tenants through a composed LevelTransition+ReadDisturb channel; every
    tenant's output must match its clean run, and the corrections must land
    in the right tenant's accounting."""
    cfg, params, prompts = tiny
    pool = _pool(cfg, 256)
    clean = _serve(_engine(cfg, params, pool), prompts)

    eng = _engine(cfg, params, pool)
    for t, p in enumerate(prompts):
        eng.submit(t, p, max_new=8)
    ch = _mixed_channel(pool.code.p, 2e-4)
    steps = changed = 0
    while eng.waiting or any(s is not None for s in eng.slots):
        eng.step()
        if steps == 2:
            changed = eng.inject(ch, key=11, n_reads=2)
        steps += 1
    assert changed > 0
    out = {s.tenant: list(s.generated) for s in eng.sequences}
    assert out == clean
    per_tenant = {t: eng.tenant_stats(t) for t in range(4)}
    assert sum(s["detected"] for s in per_tenant.values()) > 0
    assert all(s["uncorrectable"] == 0 for s in per_tenant.values())
    assert all(s["corrected"] == s["detected"]
               for s in per_tenant.values())


def test_injection_scoped_to_tenants(tiny):
    """`inject(..., tenants=[...])` corrupts only the named tenants' pages;
    the others read clean storage and bank zero corrections."""
    cfg, params, prompts = tiny
    pool = _pool(cfg, 256)
    eng = _engine(cfg, params, pool)
    for t, p in enumerate(prompts):
        eng.submit(t, p, max_new=8)
    ch = _mixed_channel(pool.code.p, 5e-3)
    steps = 0
    while eng.waiting or any(s is not None for s in eng.slots):
        eng.step()
        if steps == 1:
            assert eng.inject(ch, key=3, n_reads=2, tenants=[0, 1]) > 0
        steps += 1
    hit = [eng.tenant_stats(t)["detected"] for t in range(4)]
    assert hit[0] > 0 and hit[1] > 0
    assert hit[2] == 0 and hit[3] == 0


@pytest.mark.slow
def test_preemption_and_resume_bit_exact(tiny):
    """A pool too small for 4 resident tenants forces LIFO preemption;
    evicted sequences readmit (re-prefill + teacher-forced replay) and
    still finish bit-exactly."""
    cfg, params, prompts = tiny
    big = _pool(cfg, 256)
    clean = _serve(_engine(cfg, params, big), prompts)
    small = _pool(cfg, 24)
    eng = _engine(cfg, params, small)
    out = _serve(eng, prompts)
    assert eng.stats()["preemptions"] > 0
    assert out == clean
    assert small.n_allocated == 0      # eviction/retire freed every block


def test_pool_exhaustion_is_clean_error(tiny):
    cfg, params, prompts = tiny
    pool = _pool(cfg, 4)               # can't hold even one sequence
    eng = _engine(cfg, params, pool)
    eng.submit(0, prompts[0], max_new=8)
    with pytest.raises(PoolExhausted):
        eng.run()


def test_submit_validates_against_max_seq(tiny):
    cfg, params, prompts = tiny
    eng = _engine(cfg, params, _pool(cfg, 64), max_seq=16)
    with pytest.raises(ValueError):
        eng.submit(0, prompts[0], max_new=8)   # 12 + 8 > 16


@pytest.mark.slow
def test_background_scrub_preserves_outputs_and_repairs(tiny):
    """Interleaved scrub sweeps must not change any tenant's tokens, and
    must actually repair the injected corruption in place."""
    cfg, params, prompts = tiny
    pool = _pool(cfg, 256)

    def noisy_run(scrub_every):
        eng = _engine(cfg, params, pool, scrub_every=scrub_every,
                      scrub_max_pages=8)
        for t, p in enumerate(prompts):
            eng.submit(t, p, max_new=8)
        ch = _mixed_channel(pool.code.p, 2e-4)
        steps = 0
        while eng.waiting or any(s is not None for s in eng.slots):
            eng.step()
            if steps == 1:
                eng.inject(ch, key=9, n_reads=2)
            steps += 1
        return {s.tenant: list(s.generated) for s in eng.sequences}, eng

    base, _ = noisy_run(0)
    scrubbed, eng = noisy_run(2)
    assert scrubbed == base
    assert pool.stats.scrub_rounds > 0
    reports = eng.scrub_reports
    assert sum(r["pages"] for r in reports) > 0
    repaired = sum(r["repaired_words"] for r in reports)
    flagged = sum(r["flagged_words"] for r in reports)
    assert repaired == flagged         # weak channel: everything repairable
