"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (per the brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeSpec
from repro.launch.cells import settings_for
from repro.launch.steps import build_train
from repro.models import (decode_step, forward, init_caches, init_params,
                          loss_fn, prefill)

ALL = ARCH_IDS + ["paper_pim"]


def _setup(arch_id, B=2, S=16):
    cfg = get_config(arch_id).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    aux = None
    if cfg.aux_kind:
        aux = 0.1 * jax.random.normal(key, (B, cfg.n_aux_tokens, cfg.d_model),
                                      jnp.float32)
    return cfg, params, tokens, aux


@pytest.mark.parametrize("arch_id", ALL)
def test_forward_shapes_no_nans(arch_id):
    cfg, params, tokens, aux = _setup(arch_id)
    logits = forward(params, cfg, tokens, aux=aux)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch_id", ALL)
def test_one_train_step(arch_id):
    cfg, params, tokens, aux = _setup(arch_id)
    import dataclasses
    shape = ShapeSpec("t", 16, 2, "train")
    st = dataclasses.replace(settings_for(arch_id, shape), microbatches=2)
    step, _, _, tx = build_train(cfg, st, shape, lr=1e-3)
    opt = tx.init(params)
    batch = {"tokens": tokens, "labels": tokens}
    if aux is not None:
        batch["aux"] = aux
    new_params, new_opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 20
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: a - b, new_params, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch_id", ALL)
def test_prefill_decode_consistency(arch_id):
    """decode_step at position S-1 with prefilled caches reproduces the last
    prefill logit (exactness: same params, same math path). MoE archs use the
    dense oracle: capacity dropping depends on the token count, which differs
    between a prefill pass and a one-token decode by construction."""
    import dataclasses
    cfg, params, tokens, aux = _setup(arch_id)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_impl="dense")
    S = tokens.shape[1]
    lgs, caches = prefill(params, cfg, tokens, aux=aux)
    lg2, _ = decode_step(params, cfg, caches, tokens[:, -1:],
                         jnp.asarray(S - 1), aux=aux)
    diff = float(jnp.max(jnp.abs(lgs[:, -1] - lg2[:, 0])))
    tol = 0.05 if any(s.kind == "mamba" for s in cfg.group_spec) else 1e-3
    assert diff <= tol, diff


@pytest.mark.parametrize("arch_id", ["gemma2_27b"])
def test_sliding_window_ring_buffer(arch_id):
    """Decode past the window: ring buffer must keep only the last W tokens."""
    cfg = get_config(arch_id).reduced()
    import dataclasses
    spec = tuple(dataclasses.replace(s, local_window=8) if s.local_window
                 else s for s in cfg.group_spec)
    cfg = dataclasses.replace(cfg, group_spec=spec)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    caches = init_caches(cfg, 1, 8)        # window-sized ring for local layer
    tok = jax.random.randint(key, (1, 1), 0, cfg.vocab_size)
    for pos in range(12):                  # wraps past the ring size
        logits, caches = decode_step(params, cfg, caches, tok, jnp.asarray(pos))
        assert not bool(jnp.isnan(logits).any())


def test_moe_capacity_paths():
    cfg = get_config("olmoe_1b_7b").reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    import dataclasses
    lg_ep = forward(params, cfg, tokens)
    cfg_d = dataclasses.replace(cfg, moe_impl="dense")
    lg_dense = forward(params, cfg_d, tokens)
    # same routing; sorted_ep may drop at capacity — allow small deviation
    corr = np.corrcoef(np.asarray(lg_ep, np.float32).ravel(),
                       np.asarray(lg_dense, np.float32).ravel())[0, 1]
    assert corr > 0.98
