"""Device-resident paged protected store + protected KV-cache serving path.

Covers the two-backend split (host `ProtectedMemoryArray` vs device
`PagedProtectedStore`), the device `encode_words` op against its oracles,
the pipelined corrected-read path, the quantization bridge, paged
online-softmax attention, and the model-stack serving integration.
"""
import types

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (decode_pipelined, decode_stream, get_code,
                        np_encode_words)
from repro.kernels.backend import policy_from_store_backend
from repro.memory import (PagedProtectedStore, ProtectedMemoryArray,
                          asymmetric_adjacent, dequantize_tensor,
                          quantize_tensor, words_for_tensor)


def _corrupt(rng, code, B, errs):
    w = rng.integers(0, code.p, (B, code.k))
    cw = np_encode_words(w, code)
    y = cw.copy()
    for b in range(B):
        pos = rng.choice(code.n, size=errs, replace=False)
        y[b, pos] = (y[b, pos] + 1) % code.p
    return jnp.asarray(y, jnp.int32), cw


# ---------------------------------------------------------------------------
# host backend round-trips (dtypes / odd shapes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "float16", "int8"])
def test_array_roundtrip_dtypes(rng, dtype):
    mem = ProtectedMemoryArray("wl40_r08")
    x = rng.normal(size=(5, 7)).astype(dtype) if dtype != "int8" \
        else rng.integers(-128, 128, (5, 7), np.int8)
    mem.write("x", x)
    out = mem.read("x")
    assert out.dtype == np.dtype(dtype) and out.shape == x.shape
    assert np.array_equal(out, x)
    out[0, 0] = out[0, 0]          # writable


def test_array_roundtrip_odd_shapes(rng):
    mem = ProtectedMemoryArray("wl40_r08")
    # 0-d scalar
    mem.write("scalar", np.float32(3.25))
    got = mem.read("scalar")
    assert got.shape == () and got == np.float32(3.25)
    # empty tensor
    mem.write("empty", np.zeros((0, 3), np.float32))
    got = mem.read("empty")
    assert got.shape == (0, 3) and got.size == 0
    # non-contiguous view: packing serializes logical order
    base = rng.normal(size=(8, 6)).astype(np.float32)
    view = base[::2, 1::2]
    assert not view.flags["C_CONTIGUOUS"]
    mem.write("view", view)
    assert np.array_equal(mem.read("view"), np.ascontiguousarray(view))


# ---------------------------------------------------------------------------
# decode_stream: boundaries + eager mesh validation
# ---------------------------------------------------------------------------


def test_decode_stream_exact_chunk_boundary(rng):
    code = get_code("wl80_r08")
    y, cw = _corrupt(rng, code, 12, 1)           # exactly 2 chunks of 6
    outs = list(decode_stream(code, y, chunk_size=6, n_iters=12,
                              damping=0.3))
    assert [o[0].shape[0] for o in outs] == [6, 6]
    got = np.concatenate([np.asarray(r.symbols) for _, r in outs])
    assert np.array_equal(got, cw)


def test_decode_stream_single_ragged_chunk(rng):
    code = get_code("wl80_r08")
    y, cw = _corrupt(rng, code, 3, 1)            # one ragged chunk < size
    outs = list(decode_stream(code, y, chunk_size=8, n_iters=12,
                              damping=0.3))
    assert len(outs) == 1 and outs[0][0].shape[0] == 3
    assert np.array_equal(np.asarray(outs[0][1].symbols), cw)


def test_decode_stream_mesh_divisibility_validated_eagerly(rng):
    code = get_code("wl40_r08")
    y, _ = _corrupt(rng, code, 4, 1)
    fake_mesh = types.SimpleNamespace(shape={"data": 3})
    with pytest.raises(ValueError, match="chunk_size=8.*mesh\\s+size 3"):
        # at CALL time — not on first next(), not deep inside shard_map
        decode_stream(code, y, chunk_size=8, mesh=fake_mesh)
    with pytest.raises(ValueError, match="chunk_size=4"):
        decode_pipelined(code, y, chunk_size=4, mesh=fake_mesh)


def test_decode_pipelined_matches_stream(rng):
    code = get_code("wl40_r08")
    y, _cw = _corrupt(rng, code, 22, 1)
    ref = [np.asarray(r.symbols) for _, r in
           decode_stream(code, y, chunk_size=8, n_iters=8, damping=0.3)]
    for depth in (1, 3):
        got = [np.asarray(r.symbols) for _, r in
               decode_pipelined(code, y, chunk_size=8, n_iters=8,
                                damping=0.3, depth=depth)]
        assert [g.shape[0] for g in got] == [8, 8, 6]
        assert np.array_equal(np.concatenate(got), np.concatenate(ref))
    with pytest.raises(ValueError, match="depth"):
        decode_pipelined(code, y, depth=0)


# ---------------------------------------------------------------------------
# device encode op: kernel vs oracle vs host
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["wl40_r08", "wl160_r08_gf5",
                                  "wl160_r08_gf7"])
def test_encode_words_kernel_matches_host_oracle(rng, name):
    from repro.kernels.ops import encode_words
    from repro.kernels.ref import encode_words_ref
    code = get_code(name)
    assert code.k * (code.p - 1) ** 2 < 2 ** 31   # int32 accumulator bound
    u = jnp.asarray(rng.integers(0, code.p, (17, code.k)), jnp.int32)
    P = jnp.asarray(code.P, jnp.int32)
    host = np_encode_words(np.asarray(u), code)
    kern = np.asarray(encode_words(u, P, code.p))
    ref = np.asarray(encode_words_ref(u, P, code.p))
    assert np.array_equal(kern, host)
    assert np.array_equal(ref, host)


def test_paged_store_encode_parity_both_backends(rng):
    code = get_code("wl80_r08")
    u = rng.integers(0, code.p, (21, code.k))
    host = np_encode_words(u, code)
    for backend in ("kernel", "ref"):
        st = PagedProtectedStore(code, page_words=8,
                                 policy=policy_from_store_backend(backend))
        st.append_words(u)
        assert np.array_equal(st.export_words().astype(np.int64), host)
        assert np.array_equal(np.asarray(st.read_info(0, 21)), u)


# ---------------------------------------------------------------------------
# paged store behavior
# ---------------------------------------------------------------------------


def test_paged_store_corrects_and_scrubs(rng):
    code = get_code("wl80_r08")
    st = PagedProtectedStore(code, page_words=16, n_iters=12)
    u = rng.integers(0, code.p, (40, code.k))
    st.append_words(u)
    # exactly one wrong cell per word (always inside wl80's correction
    # strength) via the channel's conditional sampler
    ch = asymmetric_adjacent(code.p, 2e-3, 1e-3)
    for i in range(st.n_pages):
        st._pages[i] = ch.corrupt_exact(jax.random.PRNGKey(i),
                                        st.page(i), 1)
    assert st.scan_flags().sum() == st.n_words
    # pipelined == synchronous whole-store read
    piped = np.concatenate([np.asarray(p) for p in
                            st.iter_corrected()])[:st.n_words]
    sync = np.asarray(st.read_corrected())
    assert np.array_equal(piped, sync)
    assert np.array_equal(sync[:, :code.k], u)          # fully corrected
    rep = st.scrub()
    # pad rows of the trailing page were corrupted too: scrub sweeps them
    assert rep["repaired_words"] == rep["flagged_words"] >= st.n_words
    assert st.scan_flags().sum() == 0                    # storage repaired


def test_paged_store_incremental_append_and_ranges(rng):
    code = get_code("wl40_r08")
    st = PagedProtectedStore(code, page_words=8)
    a0 = rng.integers(0, code.p, (5, code.k))
    a1 = rng.integers(0, code.p, (9, code.k))
    r0 = st.append_words(a0)
    r1 = st.append_words(a1)
    assert r0 == (0, 5) and r1 == (5, 14) and st.n_pages == 2
    assert np.array_equal(np.asarray(st.read_info(*r1)), a1)
    # empty and page-aligned ranges are valid, not IndexErrors
    assert st.read_words(14, 14).shape == (0, code.n)
    assert st.read_words(8, 14).shape == (6, code.n)
    empty = PagedProtectedStore(code, page_words=8)
    assert empty.read_words(0, 0).shape == (0, code.n)
    with pytest.raises(ValueError, match="word range"):
        st.read_words(0, 99)
    with pytest.raises(ValueError, match="info words"):
        st.append_words(np.zeros((2, code.k + 1), np.int64))


def test_paged_store_adopts_host_encoded_words(rng):
    """Backend interop: host-encoded checkpoint words serve from the device
    store without re-encoding."""
    code = get_code("wl40_r08")
    mem = ProtectedMemoryArray(code)
    x = rng.normal(size=(4, 4)).astype(np.float32)
    mem.write("x", x)
    st = PagedProtectedStore(code, page_words=8)
    lo, hi = st.append_encoded(mem.stored("x").enc)
    host_words = mem.stored("x").enc.astype(np.int64) % code.p
    assert np.array_equal(np.asarray(st.read_words(lo, hi)), host_words)


def test_paged_store_validation():
    with pytest.raises(ValueError, match="page_words"):
        PagedProtectedStore("wl40_r08", page_words=0)
    with pytest.raises(ValueError, match="backend"):
        policy_from_store_backend("gpu")
    with pytest.raises(TypeError, match="backend"):
        PagedProtectedStore("wl40_r08", backend="ref")  # noqa: RPL006  # asserts the kwarg removal
    fake_mesh = types.SimpleNamespace(shape={"data": 3})
    with pytest.raises(ValueError, match="page_words=8.*mesh"):
        PagedProtectedStore("wl40_r08", page_words=8, mesh=fake_mesh)


# ---------------------------------------------------------------------------
# quantization bridge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_roundtrip_within_step(rng, dtype):
    code = get_code("wl40_r08")
    x = jnp.asarray(rng.normal(size=(3, 5, 2)), dtype)
    w, meta = quantize_tensor(x, code.p, code.k)
    assert w.shape == (words_for_tensor(x.shape, code.p, code.k), code.k)
    assert int(w.min()) >= 0 and int(w.max()) < code.p
    back = dequantize_tensor(w, meta, code.p)
    assert back.dtype == x.dtype and back.shape == x.shape
    err = jnp.max(jnp.abs(back.astype(jnp.float32) - x.astype(jnp.float32)))
    # absmax int8: half a quantization step (+ bf16 representation error)
    assert float(err) <= float(meta.scale) * 0.51 + 0.01


# ---------------------------------------------------------------------------
# paged attention == dense attention
# ---------------------------------------------------------------------------


def test_paged_attention_matches_dense(rng):
    from repro.nn.layers import _attend, _attend_paged
    B, Sq, Hq, Hkv, D, T = 2, 1, 4, 2, 8, 5
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), jnp.float32)
    ks = [jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
          for _ in range(3)]
    vs = [jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
          for _ in range(3)]
    valid_last = 2                               # ragged hot page
    pages = [(ks[0], vs[0], T), (ks[1], vs[1], T),
             (ks[2], vs[2], valid_last)]
    out = _attend_paged(q, iter(pages), 0.0)
    k_all = jnp.concatenate([ks[0], ks[1], ks[2][:, :valid_last]], axis=1)
    v_all = jnp.concatenate([vs[0], vs[1], vs[2][:, :valid_last]], axis=1)
    ref = _attend(q, k_all, v_all, None, 0.0, causal=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0, atol=2e-2)


# ---------------------------------------------------------------------------
# protected KV serving through the model stack
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_lm():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("paper_pim").reduced(n_groups=2, d_model=32,
                                          n_heads=2, d_ff=64, vocab=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _decode_some(params, cfg, caches, toks, S, steps=3):
    from repro.models import decode_step
    tok = toks[:, -1:]
    outs = []
    for i in range(steps):
        logits, caches = decode_step(params, cfg, caches, tok,
                                     jnp.asarray(S + i))
        outs.append(logits)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.concatenate(outs, axis=1), caches


def test_protected_kv_serving_matches_dense(tiny_lm):
    from repro.models import ProtectedKVConfig, init_caches, prefill
    cfg, params = tiny_lm
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    lg_d, dense = prefill(params, cfg, toks)
    full = init_caches(cfg, B, S + 4)
    dense = jax.tree.map(
        lambda d, s: s if d.shape == s.shape
        else jnp.pad(s, [(0, a - b) for a, b in zip(d.shape, s.shape,
                                                    strict=True)]),
        full, dense)
    ref, _ = _decode_some(params, cfg, dense, toks, S)

    pkv = ProtectedKVConfig(code_name="wl40_r08", page_tokens=4)
    lg_p, pc = prefill(params, cfg, toks, protected_kv=pkv, max_seq=S + 4)
    assert np.allclose(np.asarray(lg_p), np.asarray(lg_d))   # same prefill
    got, pc = _decode_some(params, cfg, pc, toks, S)
    # int8-quantized KV: logits agree to quantization noise
    assert float(jnp.max(jnp.abs(got - ref))) < 0.05
    st = pc.stats()
    assert st["protected_layers"] == cfg.n_groups
    assert st["tokens"] == S + 3


def test_protected_kv_serving_corrects_corruption(tiny_lm):
    from repro.models import ProtectedKVConfig, prefill
    cfg, params = tiny_lm
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    ch = asymmetric_adjacent(3, 5e-4, 5e-4)

    def run(corrected, inject):
        pkv = ProtectedKVConfig(code_name="wl80_r08", page_tokens=4,
                                corrected=corrected, n_iters=16)
        _lg, pc = prefill(params, cfg, toks, protected_kv=pkv,
                          max_seq=S + 4)
        if inject:
            assert pc.inject(ch, key=5) > 0
        out, pc = _decode_some(params, cfg, pc, toks, S)
        return np.asarray(out), pc

    clean, _ = run(True, False)
    corrected, pc = run(True, True)
    raw, _ = run(False, True)
    # the decoder restores the exact stored words -> identical logits
    assert np.array_equal(corrected, clean)
    # the raw-level ablation actually sees the corruption
    assert not np.array_equal(raw, clean)
    # scrub repairs storage in place
    rep = pc.scrub()
    assert rep["repaired_words"] == rep["flagged_words"] > 0
    assert pc.stats()["flagged_words"] == 0


def _store_levels(store):
    return np.concatenate([np.asarray(pg) for pg in store._iter_pages()])


def test_kv_inject_keys_independent_per_layer_and_store(tiny_lm):
    """Regression: `ProtectedKVCaches.inject` must derive an independent
    subkey per layer (fold_in) and per K/V store (split) — one shared key
    used to corrupt every store with the same pattern, which understates
    multi-layer corruption."""
    import itertools
    from repro.models import ProtectedKVConfig, prefill
    cfg, params = tiny_lm
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    pkv = ProtectedKVConfig(code_name="wl80_r08", page_tokens=4)
    _lg, pc = prefill(params, cfg, toks, protected_kv=pkv, max_seq=16)
    assert len(pc.layers) >= 2
    clean = {name: (_store_levels(lyr.k_store), _store_levels(lyr.v_store))
             for name, lyr in pc.layers.items()}
    ch = asymmetric_adjacent(3, 0.02, 0.02)
    assert pc.inject(ch, key=7) > 0
    masks = {}
    for name, lyr in pc.layers.items():
        km = _store_levels(lyr.k_store) != clean[name][0]
        vm = _store_levels(lyr.v_store) != clean[name][1]
        assert km.any() and vm.any()       # every layer was actually hit
        assert not np.array_equal(km, vm)  # K and V draw split halves
        masks[name] = (km, vm)
    for a, b in itertools.combinations(sorted(masks), 2):
        assert not np.array_equal(masks[a][0], masks[b][0])
        assert not np.array_equal(masks[a][1], masks[b][1])


def test_kv_inject_counter_advances_without_key(tiny_lm):
    """Keyless injections draw fresh fold_in subkeys each call — two
    consecutive injections never repeat an error pattern."""
    from repro.models import ProtectedKVConfig, prefill
    cfg, params = tiny_lm
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    pkv = ProtectedKVConfig(code_name="wl80_r08", page_tokens=4)
    _lg, pc = prefill(params, cfg, toks, protected_kv=pkv, max_seq=16)
    lyr = pc.layers[sorted(pc.layers)[0]]
    ch = asymmetric_adjacent(3, 0.02, 0.02)
    s0 = _store_levels(lyr.k_store)
    assert pc.inject(ch) > 0
    s1 = _store_levels(lyr.k_store)
    assert pc.inject(ch) > 0
    s2 = _store_levels(lyr.k_store)
    m1, m2 = s1 != s0, s2 != s1
    assert m1.any() and m2.any()
    assert not np.array_equal(m1, m2)
