"""MoE: sorted-EP production path vs dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.nn.moe import init_moe, moe_dense, moe_sorted_ep


def _cfg(T=32, E=8, k=2, cf=8.0):
    base = get_config("olmoe_1b_7b").reduced(n_experts=E)
    return dataclasses.replace(base, top_k=k, capacity_factor=cf)


@pytest.mark.parametrize("E,k", [(4, 1), (8, 2), (8, 8)])
def test_sorted_ep_matches_dense_with_ample_capacity(rng, E, k):
    cfg = _cfg(E=E, k=k, cf=float(E))          # capacity >= all tokens
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg, 32)
    x = jnp.asarray(rng.normal(size=(24, cfg.d_model)).astype(np.float32))
    y_d = moe_dense(params, x.astype(jnp.bfloat16), cfg)
    y_s = moe_sorted_ep(params, x.astype(jnp.bfloat16), cfg)
    np.testing.assert_allclose(np.asarray(y_d, np.float32),
                               np.asarray(y_s, np.float32),
                               rtol=0.1, atol=0.05)


def test_capacity_dropping(rng):
    """With capacity factor << 1 some tokens must be dropped to zero."""
    cfg = _cfg(E=4, k=1, cf=0.3)
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg, 32)
    x = jnp.asarray(rng.normal(size=(64, cfg.d_model)).astype(np.float32)).astype(jnp.bfloat16)
    y = moe_sorted_ep(params, x, cfg)
    zero_rows = (np.abs(np.asarray(y, np.float32)).sum(-1) == 0).sum()
    assert zero_rows > 0


def test_routing_is_topk(rng):
    from repro.nn.moe import _route
    cfg = _cfg(E=8, k=2)
    key = jax.random.PRNGKey(1)
    params = init_moe(key, cfg, 32)
    x = jnp.asarray(rng.normal(size=(16, cfg.d_model)).astype(np.float32)).astype(jnp.bfloat16)
    topi, w = _route(params, x, cfg)
    assert topi.shape == (16, 2)
    assert np.allclose(np.asarray(w, np.float32).sum(-1), 1.0, atol=2e-2)
    # indices are the true argmax-2 of the router logits
    logits = np.asarray(x @ params["router"].astype(jnp.bfloat16), np.float32)
    ref = np.argsort(-logits, axis=-1)[:, :2]
    assert (np.sort(np.asarray(topi), -1) == np.sort(ref, -1)).all()
