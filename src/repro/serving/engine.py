"""Multi-tenant protected serving engine: continuous batching over a shared
NB-LDPC-protected page pool.

PR 5's serving path protects ONE sequence: one `ProtectedKVCaches`, grow-only
pages, a Python loop per decode step. This module is the layer the ROADMAP's
"millions of users" item asks for — a vLLM-style engine that amortizes the
protected datapath across many concurrent sequences:

- **slots** — the engine owns `max_active` batch slots. Every jitted
  executable (embed, attention, head) runs at batch `max_active` whatever
  the occupancy, so admitting more tenants raises aggregate tokens/s at
  near-constant step latency (the scaling the multi-tenant benchmark
  measures), and a sequence's row-computation is independent of which other
  slots are occupied — single-tenant and 16-tenant runs of the same engine
  shape are bit-exact per tenant.
- **block tables** — each slot's K/V pages live in a shared
  `repro.memory.pool.ProtectedPagePool` through per-tenant `PooledStore`
  block tables (one store per slot per layer per K/V). Admission preflights
  pool capacity; a freeze that would exhaust the pool preempts the
  youngest sequence (vLLM-style LIFO preemption) instead of corrupting
  state, returning its blocks to the free list.
- **preemption / resume** — a preempted sequence keeps its token history
  only. Readmission re-prefills the original prompt and replays the
  generated tokens teacher-forced through the normal batched decode path,
  which reconstructs the exact quantize-on-freeze page contents — resumed
  sequences continue bit-exactly, concurrently with live tenants.
- **background scrub** — every `scrub_every` steps the engine runs a
  bounded `pool.scrub(max_pages=...)` sweep over cold pool pages between
  decode steps (the PR 4 iterator machinery, pool-wide), with repairs
  attributed to the owning tenant.
- **per-tenant accounting** — each slot's `PooledStore.stats` counts
  detected/corrected/uncorrectable on that tenant's reads; the engine
  aggregates them (plus the pool's per-owner scrub report) in
  `tenant_stats`.
- **observability** — under `repro.obs` ambient contexts each step emits
  an `engine.step` span (admit/prefill/decode/scrub children, preemption
  instants) to the Chrome-trace tracer, counters/latency histograms to
  the metrics registry (`publish_metrics` adds per-tenant gauges), and
  the RAS estimator both ingests scrub telemetry and drives the scrub
  schedule (adaptive interval + flag-hot page prioritization). With no
  telemetry installed every hook is a no-op attribute check.

The engine drives the unmodified model stack: `repro.models.lm.decode_step`
routes `EngineCaches` (duck-typed `ProtectedKVCaches` surface, (B,) per-slot
positions) through the same `_apply_block` / `KVSource.attend` code the
single-tenant path uses — by default the fused GF-page attention kernel
(`repro.kernels.ops.attend_protected`), with the streaming `_attend_paged`
path as the exact-parity fallback.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.memory.controller import ControllerStats
from repro.memory.pool import (PoolExhausted, PooledStore, ProtectedPagePool)
from repro.memory.paged import (dequantize_tensor, quantize_tensor,
                                words_for_tensor)
from repro.models.kv import ProtectedKVConfig
from repro.nn.kv_source import KVSource
from repro.nn.layers import CDT
from repro.obs import metrics as obs_metrics
from repro.obs import ras as obs_ras
from repro.obs import trace as obs_trace
from repro.obs.trace import span

__all__ = ["BatchedPagedKV", "BatchedDenseKV", "EngineCaches",
           "SequenceState", "ServingEngine"]


@jax.jit
def _scatter_rows(buf, rows, pos):
    """Per-slot scatter: buf (B,T,H,D), rows (B,1,H,D), pos (B,) — write
    row b at buf[b, pos[b]]. One cached executable serves every step."""
    return jax.vmap(
        lambda b, r, p: jax.lax.dynamic_update_slice_in_dim(b, r, p, axis=0)
    )(buf, rows, pos)


class BatchedPagedKV(KVSource):
    """One attention layer's K/V for `max_active` slots: a shared dense hot
    page block with per-slot fill levels, and per-slot pool-backed frozen
    pages (`PooledStore` block tables into the shared pool).

    Slots freeze independently — when slot b's hot row reaches `page_tokens`
    it alone is quantized + device-encoded into b's stores. Implements
    `KVSource`: the fused `attend` stacks per-slot *corrected GF codeword
    pages* into (NP, B, W, n) kernel operands (empty slots contribute zero
    pages with scale 0 and valid 0 — exact no-ops) and runs
    `ops.attend_protected`; the streaming `pages()` path stacks decoded
    (B, T, Hkv, D) steps for the per-page online-softmax and stays as the
    exact-parity reference. Rows are computation-independent, so a slot's
    attention output does not depend on the other slots' contents."""

    kind = "protected"

    def __init__(self, pkv: ProtectedKVConfig, pool: ProtectedPagePool,
                 max_active: int, hkv: int, dh: int, dtype=CDT):
        self.pkv, self.pool = pkv, pool
        self.max_active = max_active
        self.T = pkv.page_tokens
        self.code = pool.code
        self.dtype = dtype
        self.page_shape = (1, self.T, hkv, dh)
        wpu = words_for_tensor(self.page_shape, self.code.p, self.code.k)
        if wpu != pool.page_words:
            raise ValueError(
                f"pool page_words={pool.page_words} != {wpu} words per "
                f"per-slot KV page {self.page_shape}; size the pool with "
                "words_for_tensor((1, page_tokens, n_kv_heads, head_dim))")
        self.words_per_page = wpu
        self.hot_k = jnp.zeros((max_active, self.T, hkv, dh), dtype)
        self.hot_v = jnp.zeros((max_active, self.T, hkv, dh), dtype)
        self.hot_len = np.zeros(max_active, np.int32)
        self.k_stores: list[PooledStore | None] = [None] * max_active
        self.v_stores: list[PooledStore | None] = [None] * max_active
        self.metas: list[list] = [[] for _ in range(max_active)]
        self._decoded: list[list] = [[] for _ in range(max_active)]
        self._stack_cache: list | None = None
        # fused-path memos: per-slot corrected GF codeword pages and the
        # stacked (NP, B, W, n) kernel operands built from them
        self._gf_decoded: list[list] = [[] for _ in range(max_active)]
        self._gf_stack_cache = None
        # which slots advance on append; the engine sets this each step
        self.active = np.zeros(max_active, bool)

    # -- slot lifecycle -----------------------------------------------------

    def open_slot(self, b: int, owner=None) -> None:
        self.k_stores[b] = PooledStore(self.pool, owner=owner)
        self.v_stores[b] = PooledStore(self.pool, owner=owner)
        self.hot_k = self.hot_k.at[b].set(0.0)
        self.hot_v = self.hot_v.at[b].set(0.0)
        self.hot_len[b] = 0
        self.metas[b] = []
        self._decoded[b] = []
        self._gf_decoded[b] = []
        self._stack_cache = None
        self._gf_stack_cache = None

    def close_slot(self, b: int) -> dict:
        """Free the slot's pool blocks. Returns the slot's accumulated
        correction counters so the engine can bank them per tenant."""
        out: dict[str, int] = {}
        for store in (self.k_stores[b], self.v_stores[b]):
            if store is not None:
                ControllerStats.add_counts(out, store.stats)
                store.free()
        for k in ControllerStats.CORRECTION_KEYS:
            out.setdefault(k, 0)
        self.k_stores[b] = self.v_stores[b] = None
        self.hot_len[b] = 0
        self.metas[b] = []
        self._decoded[b] = []
        self._gf_decoded[b] = []
        self._stack_cache = None
        self._gf_stack_cache = None
        return out

    # -- write path ---------------------------------------------------------

    def _freeze_rows(self, b: int, kpage: jnp.ndarray,
                     vpage: jnp.ndarray) -> None:
        """Quantize + device-encode one (1, T, Hkv, D) page into slot b's
        stores (write-through memoizing the decoded view, like the
        single-tenant `ProtectedKVLayer._freeze`)."""
        p, kk = self.code.p, self.code.k
        kw, kmeta = quantize_tensor(kpage, p, kk)
        vw, vmeta = quantize_tensor(vpage, p, kk)
        self.k_stores[b].append_words(kw)
        self.v_stores[b].append_words(vw)
        self.metas[b].append((kmeta, vmeta))
        self._decoded[b].append((dequantize_tensor(kw, kmeta, p),
                                 dequantize_tensor(vw, vmeta, p)))
        # fused-path write-through: the store page just written IS the
        # corrected codeword page
        j = self.k_stores[b].n_pages - 1
        self._gf_decoded[b].append((self.k_stores[b].page(j),
                                    self.v_stores[b].page(j)))
        self._stack_cache = None
        self._gf_stack_cache = None

    def _freeze_slot(self, b: int) -> None:
        self._freeze_rows(b, self.hot_k[b:b + 1], self.hot_v[b:b + 1])
        self.hot_len[b] = 0   # stale hot rows are masked by valid and
                              # overwritten by the next scatters

    def ingest_slot(self, b: int, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Adopt a prompt's (1, S, Hkv, D) K/V into slot b: full pages
        freeze (quantize + encode), the remainder seeds the hot row."""
        S = k.shape[1]
        T = self.T
        for j in range(S // T):
            self._freeze_rows(b, k[:, j * T:(j + 1) * T],
                              v[:, j * T:(j + 1) * T])
        rem = S % T
        if rem:
            pad = [(0, 0), (0, T - rem), (0, 0), (0, 0)]
            self.hot_k = self.hot_k.at[b].set(
                jnp.pad(k[:, S - rem:], pad)[0].astype(self.dtype))
            self.hot_v = self.hot_v.at[b].set(
                jnp.pad(v[:, S - rem:], pad)[0].astype(self.dtype))
        else:
            self.hot_k = self.hot_k.at[b].set(0.0)
            self.hot_v = self.hot_v.at[b].set(0.0)
        self.hot_len[b] = rem

    def append(self, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """One decode step's (B, 1, Hkv, D) K/V: scatter every row at its
        slot's hot position, advance active slots, freeze any slot whose
        hot row filled. Inactive slots' scatters land on masked positions
        and are overwritten by their next real token."""
        pos = jnp.asarray(self.hot_len, jnp.int32)
        self.hot_k = _scatter_rows(self.hot_k, k.astype(self.dtype), pos)
        self.hot_v = _scatter_rows(self.hot_v, v.astype(self.dtype), pos)
        self.hot_len = self.hot_len + self.active.astype(np.int32)
        for b in np.nonzero(self.hot_len >= self.T)[0]:
            self._freeze_slot(int(b))

    # -- read path ----------------------------------------------------------

    def invalidate(self) -> None:
        """Drop memoized decoded views (pool storage changed under them);
        the next read decodes through each slot's stores."""
        for b in range(self.max_active):
            self._decoded[b] = [None] * len(self.metas[b])
            self._gf_decoded[b] = [None] * len(self.metas[b])
        self._stack_cache = None
        self._gf_stack_cache = None

    def _decoded_page(self, b: int, j: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        ent = self._decoded[b][j]
        if ent is None:
            kmeta, vmeta = self.metas[b][j]
            p, kk = self.code.p, self.code.k
            if self.pkv.corrected:
                kpg = self.k_stores[b].read_page_corrected(j)
                vpg = self.v_stores[b].read_page_corrected(j)
            else:
                kpg = self.k_stores[b].page(j)
                vpg = self.v_stores[b].page(j)
            ent = (dequantize_tensor(kpg[:, :kk], kmeta, p),
                   dequantize_tensor(vpg[:, :kk], vmeta, p))
            self._decoded[b][j] = ent
        return ent

    def _stacked_page(self, j: int):
        zero = jnp.zeros(self.page_shape, self.dtype)
        ks, vs, valid = [], [], []
        for b in range(self.max_active):
            if j < len(self.metas[b]):
                kd, vd = self._decoded_page(b, j)
                ks.append(kd.astype(self.dtype))
                vs.append(vd.astype(self.dtype))
                valid.append(self.T)
            else:
                ks.append(zero)
                vs.append(zero)
                valid.append(0)
        return (jnp.concatenate(ks), jnp.concatenate(vs),
                jnp.asarray(valid, jnp.int32))

    def pages(self):
        """Yield (k (B,T,Hkv,D), v, valid (B,)) page steps for the streaming
        online-softmax: frozen page j stacks slot b's decoded page j (or a
        masked zero page), the shared hot block rides last with per-slot
        fill. Stacked frozen pages are memoized between freezes."""
        max_pg = max((len(m) for m in self.metas), default=0)
        if self._stack_cache is None or len(self._stack_cache) != max_pg:
            self._stack_cache = [self._stacked_page(j)
                                 for j in range(max_pg)]
        yield from self._stack_cache
        yield (self.hot_k, self.hot_v, jnp.asarray(self.hot_len, jnp.int32))

    # -- fused read path ----------------------------------------------------

    def _gf_page(self, b: int, j: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Slot b's corrected GF codeword page j (scan-gated decode through
        the slot's stores, corrections attributed to the owning tenant)."""
        ent = self._gf_decoded[b][j]
        if ent is None:
            ent = (self.k_stores[b].read_page_corrected(j),
                   self.v_stores[b].read_page_corrected(j))
            self._gf_decoded[b][j] = ent
        return ent

    def _fused_inputs(self):
        """Stacked kernel operands: kpages/vpages (NP, B, W, n) int32,
        kscales/vscales (NP, B) f32, valid (NP, B) int32. Page step j
        stacks slot b's page j; slots with fewer pages contribute zero
        pages (valid codewords) with scale 0 and valid 0 — exact no-ops in
        the recurrence. Memoized between freezes."""
        max_pg = max((len(m) for m in self.metas), default=0)
        if (self._gf_stack_cache is None
                or self._gf_stack_cache[0] != max_pg):
            W, n = self.words_per_page, self.code.n
            zero_pg = jnp.zeros((W, n), jnp.int32)
            zero_sc = jnp.zeros((), jnp.float32)
            steps = []
            for j in range(max_pg):
                kps, vps, kss, vss, valid = [], [], [], [], []
                for b in range(self.max_active):
                    if j < len(self.metas[b]):
                        kpg, vpg = self._gf_page(b, j)
                        kmeta, vmeta = self.metas[b][j]
                        kps.append(kpg)
                        vps.append(vpg)
                        kss.append(jnp.asarray(kmeta.scale, jnp.float32))
                        vss.append(jnp.asarray(vmeta.scale, jnp.float32))
                        valid.append(self.T)
                    else:
                        kps.append(zero_pg)
                        vps.append(zero_pg)
                        kss.append(zero_sc)
                        vss.append(zero_sc)
                        valid.append(0)
                steps.append((jnp.stack(kps), jnp.stack(vps),
                              jnp.stack(kss), jnp.stack(vss),
                              jnp.asarray(valid, jnp.int32)))
            # pre-pad the page axis to its np_bucket size with no-op zero
            # pages here (once per freeze), so the per-step
            # attend_protected call pads nothing and issues one dispatch
            from repro.kernels.ops import np_bucket
            B = self.max_active
            NB = np_bucket(max_pg)
            ops_in = (jnp.zeros((NB, B, W, n), jnp.int32),
                      jnp.zeros((NB, B, W, n), jnp.int32),
                      jnp.zeros((NB, B), jnp.float32),
                      jnp.zeros((NB, B), jnp.float32),
                      jnp.zeros((NB, B), jnp.int32))
            if steps:
                ops_in = tuple(
                    z.at[:max_pg].set(jnp.stack([s[i] for s in steps]))
                    for i, z in enumerate(ops_in))
            self._gf_stack_cache = (max_pg, ops_in)
        return self._gf_stack_cache[1]

    def attend(self, q, softcap=0.0):
        """Fused one-kernel batched read (`ops.attend_protected` over the
        per-slot GF page stacks; the shared hot block is applied inside the
        kernel with per-slot fill levels). Streams `pages()` through the
        per-page online-softmax when fusion is off or reads are
        uncorrected."""
        if not (self.pkv.fused and self.pkv.corrected):
            return super().attend(q, softcap)
        kp, vp, ks, vs, valid = self._fused_inputs()
        from repro.kernels import ops
        return ops.attend_protected(
            q, kp, vp, ks, vs, valid, self.hot_k, self.hot_v,
            jnp.asarray(self.hot_len, jnp.int32),
            p=self.code.p, k_info=self.code.k, page_shape=self.page_shape,
            softcap=float(softcap or 0.0), with_hot=True)

    # -- capacity -----------------------------------------------------------

    def freeze_candidates(self, active: np.ndarray) -> int:
        """Pool pages the NEXT step's appends will allocate (2 per slot
        about to fill its hot row) — the engine's preflight input."""
        about = active & (self.hot_len == self.T - 1)
        return 2 * int(about.sum())

    def slot_pages(self, b: int) -> list[int]:
        out: list[int] = []
        for store in (self.k_stores[b], self.v_stores[b]):
            if store is not None:
                out.extend(store.block_table)
        return out


class BatchedDenseKV(KVSource):
    """The unprotected baseline: per-slot dense K/V rows in one
    (max_active, max_seq, Hkv, D) buffer, served through the same
    `KVSource` interface (a single `pages()` step with per-slot valid
    lengths, attended via the default streaming path)."""

    kind = "dense"

    def __init__(self, max_active: int, max_seq: int, hkv: int, dh: int,
                 dtype=CDT):
        self.max_active, self.max_seq = max_active, max_seq
        self.k = jnp.zeros((max_active, max_seq, hkv, dh), dtype)
        self.v = jnp.zeros((max_active, max_seq, hkv, dh), dtype)
        self.len = np.zeros(max_active, np.int32)
        self.dtype = dtype
        self.active = np.zeros(max_active, bool)

    def open_slot(self, b: int, owner=None) -> None:
        self.k = self.k.at[b].set(0.0)
        self.v = self.v.at[b].set(0.0)
        self.len[b] = 0

    def close_slot(self, b: int) -> dict:
        self.len[b] = 0
        return dict.fromkeys(ControllerStats.CORRECTION_KEYS, 0)

    def ingest_slot(self, b: int, k: jnp.ndarray, v: jnp.ndarray) -> None:
        S = k.shape[1]
        pad = [(0, 0), (0, self.max_seq - S), (0, 0), (0, 0)]
        self.k = self.k.at[b].set(jnp.pad(k, pad)[0].astype(self.dtype))
        self.v = self.v.at[b].set(jnp.pad(v, pad)[0].astype(self.dtype))
        self.len[b] = S

    def append(self, k: jnp.ndarray, v: jnp.ndarray) -> None:
        pos = jnp.asarray(self.len, jnp.int32)
        self.k = _scatter_rows(self.k, k.astype(self.dtype), pos)
        self.v = _scatter_rows(self.v, v.astype(self.dtype), pos)
        self.len = self.len + self.active.astype(np.int32)

    def invalidate(self) -> None:
        pass

    def pages(self):
        yield self.k, self.v, jnp.asarray(self.len, jnp.int32)

    def freeze_candidates(self, active: np.ndarray) -> int:
        return 0

    def slot_pages(self, b: int) -> list[int]:
        return []


class EngineCaches:
    """The engine's cache manager: the `view`/`update` surface
    `repro.models.lm._decode_step_protected` drives, one batched KV layer
    per attention position."""

    is_protected_manager = True

    def __init__(self, cfg: ArchConfig,
                 layers: dict[tuple[int, int], Any]):
        self.cfg = cfg
        self.layers = layers

    def view(self, g: int, i: int):
        return self.layers[(g, i)]               # a KVSource

    def update(self, g: int, i: int, new_cache) -> None:
        return None

    def set_active(self, active: np.ndarray) -> None:
        for layer in self.layers.values():
            layer.active = active


@dataclasses.dataclass
class SequenceState:
    """One tenant's request through the engine."""

    tenant: Any
    prompt: np.ndarray                  # (S,) int token ids
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)
    status: str = "waiting"             # waiting | active | done
    slot: int | None = None
    replay_idx: int = 0                 # next generated token to feed
    admit_step: int = -1
    preemptions: int = 0
    stats: dict[str, int] = dataclasses.field(
        default_factory=lambda: dict.fromkeys(
            ControllerStats.CORRECTION_KEYS, 0))

    @property
    def done(self) -> bool:
        return self.status == "done"


class ServingEngine:
    """Continuous-batching scheduler over `max_active` slots.

    `submit()` queues sequences; each `step()` admits what fits (pool
    capacity preflighted), runs ONE batched decode step for every active
    slot (greedy sampling), retires finished sequences, and interleaves a
    bounded background scrub of cold pool pages. Preemption (LIFO — the
    youngest sequence yields, vLLM-style) frees blocks when a step's
    freezes would exhaust the pool; preempted sequences readmit by
    re-prefilling their prompt and replaying generated tokens teacher-
    forced, which is bit-exact with never having been evicted."""

    def __init__(self, params, cfg: ArchConfig, *,
                 pkv: ProtectedKVConfig | None = None,
                 pool: ProtectedPagePool | None = None,
                 max_active: int = 16, max_seq: int = 512,
                 protected: bool = True, scrub_every: int = 0,
                 scrub_max_pages: int = 4, scrub_min_age: int = 0):
        self.params, self.cfg = params, cfg
        self.max_active, self.max_seq = max_active, max_seq
        self.protected = protected
        self.scrub_every = scrub_every
        self.scrub_max_pages = scrub_max_pages
        self.scrub_min_age = scrub_min_age
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        for spec in cfg.group_spec:
            if not (spec.kind == "attn" and not spec.cross
                    and not spec.local_window):
                raise ValueError(
                    "ServingEngine serves global self-attention stacks; "
                    f"layer kind {spec.kind!r} (cross={spec.cross}, "
                    f"window={spec.local_window}) is not batchable here")
        layers: dict[tuple[int, int], Any] = {}
        if protected:
            self.pkv = pkv or ProtectedKVConfig()
            wpu = words_for_tensor((1, self.pkv.page_tokens, hkv, dh),
                                   _code(self.pkv).p, _code(self.pkv).k)
            if pool is None:
                pool = ProtectedPagePool(
                    _code(self.pkv), page_words=wpu,
                    capacity_pages=self._default_capacity(cfg, max_active),
                    n_iters=self.pkv.n_iters, damping=self.pkv.damping,
                    mesh=self.pkv.mesh)
            self.pool = pool
            for g in range(cfg.n_groups):
                for i in range(len(cfg.group_spec)):
                    layers[(g, i)] = BatchedPagedKV(
                        self.pkv, pool, max_active, hkv, dh)
        else:
            self.pkv = pkv
            self.pool = None
            for g in range(cfg.n_groups):
                for i in range(len(cfg.group_spec)):
                    layers[(g, i)] = BatchedDenseKV(max_active, max_seq,
                                                    hkv, dh)
        self.caches = EngineCaches(cfg, layers)
        self.n_stores = 2 * len(layers)      # pool pages per frozen KV page
        self.waiting: deque = deque()
        self.slots: list[SequenceState | None] = [None] * max_active
        self.sequences: list[SequenceState] = []
        self._step_no = 0
        self.scrub_reports: list[dict] = []

    def _default_capacity(self, cfg: ArchConfig, max_active: int) -> int:
        pages_per_seq = -(-self.max_seq // self.pkv.page_tokens)
        n_layers = cfg.n_groups * len(cfg.group_spec)
        return max_active * pages_per_seq * 2 * n_layers

    # -- submission ---------------------------------------------------------

    def submit(self, tenant, prompt, max_new: int) -> SequenceState:
        prompt = np.asarray(prompt, np.int64).reshape(-1)
        if len(prompt) + max_new > self.max_seq:
            raise ValueError(f"prompt {len(prompt)} + max_new {max_new} "
                             f"exceeds max_seq {self.max_seq}")
        seq = SequenceState(tenant=tenant, prompt=prompt, max_new=max_new)
        self.waiting.append(seq)
        self.sequences.append(seq)
        return seq

    # -- scheduling ---------------------------------------------------------

    def _admission_pages(self, seq: SequenceState) -> int:
        if not self.protected:
            return 0
        return (len(seq.prompt) // self.pkv.page_tokens) * self.n_stores

    def _admit(self) -> list[SequenceState]:
        assigns: list[tuple[SequenceState, int]] = []
        reserved: set = set()
        pending_pages = 0
        while self.waiting:
            free = [b for b in range(self.max_active)
                    if self.slots[b] is None and b not in reserved]
            if not free:
                break
            seq = self.waiting[0]
            need = self._admission_pages(seq)
            if (self.protected
                    and pending_pages + need > self.pool.available):
                break
            self.waiting.popleft()
            assigns.append((seq, free[0]))
            reserved.add(free[0])
            pending_pages += need
        # one padded (max_active, S) prefill per distinct prompt length:
        # rows are computation-independent, so a prompt's row is bit-exact
        # whether it shares the batch with 15 other admits or 15 pad rows —
        # and admitting a full engine costs one forward pass, not max_active
        by_len: dict[int, list[tuple[SequenceState, int]]] = {}
        for seq, b in assigns:
            by_len.setdefault(len(seq.prompt), []).append((seq, b))
        for S, group in sorted(by_len.items()):
            self._prefill_group(S, group)
        return [seq for seq, _ in assigns]

    def _prefill_group(self, S: int,
                       group: list[tuple[SequenceState, int]]) -> None:
        from repro.models import lm
        tokens = np.zeros((self.max_active, S), np.int64)
        for j, (seq, _b) in enumerate(group):
            tokens[j] = seq.prompt
        with span("engine.prefill", prompt_len=S, n_seqs=len(group),
                  tenants=[str(s.tenant) for s, _ in group]):
            logits, caches = lm.prefill(self.params, self.cfg,
                                        jnp.asarray(tokens, jnp.int32))
        for j, (seq, b) in enumerate(group):
            for (g, i), layer in self.caches.layers.items():
                entry = caches[f"pos{i}"]
                layer.open_slot(b, owner=seq.tenant)
                layer.ingest_slot(b, entry["k"][g][j:j + 1, :S],
                                  entry["v"][g][j:j + 1, :S])
            if not seq.generated:
                # the prefill's last logit yields the first generated token
                seq.generated.append(int(jnp.argmax(logits[j, -1])))
            seq.replay_idx = 0
            seq.slot = b
            seq.status = "active"
            seq.admit_step = self._step_no
            self.slots[b] = seq
            if len(seq.generated) >= seq.max_new:
                # max_new == 1: the prefill already produced the only token
                self._release_slot(seq)
                seq.status = "done"

    def _release_slot(self, seq: SequenceState) -> None:
        # the ONLY place slot-store counters enter seq.stats: stores are
        # freed by close_slot in the same motion, so a counter is banked
        # exactly once (tenant_stats sums banked + still-live, never both)
        for layer in self.caches.layers.values():
            ControllerStats.add_counts(seq.stats, layer.close_slot(seq.slot))
        self.slots[seq.slot] = None
        seq.slot = None

    def _preempt_one(self) -> int | None:
        """Evict the youngest active sequence (LIFO, vLLM-style): cheapest
        to replay, and the oldest tenants keep streaming. Returns the freed
        slot index."""
        live = [s for s in self.slots if s is not None]
        if len(live) <= 1:
            return None
        victim = max(live, key=lambda s: (s.admit_step, s.slot))
        slot = victim.slot
        self._release_slot(victim)
        victim.status = "waiting"
        victim.preemptions += 1
        victim.replay_idx = 0
        self.waiting.appendleft(victim)   # readmit first
        return slot

    def _preflight(self, active_mask: np.ndarray) -> None:
        if not self.protected:
            return
        while True:
            needed = sum(layer.freeze_candidates(active_mask)
                         for layer in self.caches.layers.values())
            if needed <= self.pool.available:
                return
            slot = self._preempt_one()
            if slot is None:
                raise PoolExhausted(
                    f"next step freezes need {needed} pool pages, only "
                    f"{self.pool.available} free and nothing to preempt — "
                    "grow capacity_pages or lower max_active")
            active_mask[slot] = False

    # -- the step -----------------------------------------------------------

    def step(self) -> dict:
        """One engine tick: admit, preflight capacity, run one batched
        decode step across the active slots, retire finished sequences,
        interleave background scrub. Returns a step report.

        Observability rides along when installed (`repro.obs`): one
        `engine.step` span per tick with admit/decode/scrub child spans,
        step counters/latency into the ambient metrics registry, and the
        RAS estimator drives the scrub schedule — `adaptive_interval`
        shrinks the nominal `scrub_every` period under flag pressure and
        sweeps flag-hot pages first (`prioritize=True`). All of it
        no-ops at one attribute check per pillar when telemetry is off."""
        t_start = time.perf_counter()
        with span("engine.step", step=self._step_no) as sp:
            report = self._step_inner(sp)
        reg = obs_metrics.current()
        if reg.enabled:
            reg.counter("engine_steps", layer="engine").inc()
            reg.counter("engine_tokens", layer="engine").inc(
                report["tokens"])
            reg.counter("engine_retired", layer="engine").inc(
                report["retired"])
            reg.counter("engine_preemptions", layer="engine").inc(
                report["preempted"])
            reg.histogram("engine_step_seconds", layer="engine").observe(
                time.perf_counter() - t_start)
            reg.gauge("engine_active_slots", layer="engine").set(
                report["active"])
        return report

    def _step_inner(self, sp) -> dict:
        from repro.models import lm
        with span("engine.admit"):
            admitted = self._admit()
        active_mask = np.zeros(self.max_active, bool)
        tokens = np.zeros((self.max_active, 1), np.int64)
        pos = np.zeros(self.max_active, np.int64)
        for b, seq in enumerate(self.slots):
            if seq is None:
                continue
            active_mask[b] = True
            tokens[b, 0] = seq.generated[seq.replay_idx]
            pos[b] = len(seq.prompt) + seq.replay_idx
        report = {"step": self._step_no, "admitted": len(admitted),
                  "active": int(active_mask.sum()), "tokens": 0,
                  "retired": 0, "preempted": 0}
        sp.set(active=report["active"], admitted=report["admitted"])
        if not active_mask.any():
            self._step_no += 1
            return report
        pre = sum(s.preemptions for s in self.sequences)
        self._preflight(active_mask)
        report["preempted"] = sum(s.preemptions
                                  for s in self.sequences) - pre
        if report["preempted"]:
            tr = obs_trace.current()
            if tr.enabled:
                tr.instant("engine.preempt", count=report["preempted"])
        if not active_mask.any():
            self._step_no += 1
            return report
        self.caches.set_active(active_mask)
        with span("engine.decode", step=self._step_no,
                  active=report["active"]):
            logits, _ = lm.decode_step(
                self.params, self.cfg, self.caches,
                jnp.asarray(tokens, jnp.int32), jnp.asarray(pos, jnp.int32))
            # keep the sampled tokens on device for now: the argmax host
            # transfer is the step's sync point, and deferring it lets the
            # background scrub's scan/drain dispatches below queue behind
            # the decode step instead of waiting out its completion
            nxt_dev = jnp.argmax(logits[:, -1, :], axis=-1)
        self._step_no += 1
        if self.protected:
            self._touch_pages()
            if self.scrub_every and self._due_for_scrub():
                # scrub moves storage TOWARD clean, so memoized decoded
                # views (themselves corrected reads) stay consistent — no
                # invalidation, which is why interleaved scrub stays cheap.
                # (Pages of sequences that retire on this step's token may
                # be swept too — harmless: they are still allocated here,
                # and attribution follows page ownership either way.)
                est = obs_ras.current()
                with span("engine.scrub") as ssp:
                    rep = self.pool.scrub(max_pages=self.scrub_max_pages,
                                          now=self._step_no,
                                          min_age=self.scrub_min_age,
                                          prioritize=est.enabled)
                    ssp.set(pages=rep["pages"],
                            flagged=rep["flagged_words"],
                            repaired=rep["repaired_words"])
                self.scrub_reports.append(rep)
                report["scrubbed_pages"] = rep["pages"]
        with span("engine.sample_sync", step=self._step_no - 1):
            nxt = np.asarray(nxt_dev)
        for b, seq in enumerate(self.slots):
            if seq is None or not active_mask[b]:
                continue
            report["tokens"] += 1
            if seq.replay_idx < len(seq.generated) - 1:
                seq.replay_idx += 1          # teacher-forced replay
            else:
                seq.generated.append(int(nxt[b]))
                seq.replay_idx += 1
            if len(seq.generated) >= seq.max_new:
                self._release_slot(seq)
                seq.status = "done"
                report["retired"] += 1
        return report

    def _due_for_scrub(self) -> bool:
        """Fixed `scrub_every` cadence, unless an ambient RAS estimator is
        installed — then the period is `adaptive_interval(scrub_every)`:
        shorter while pages flag above the estimator's target rate, longer
        when the pool is quiet."""
        est = obs_ras.current()
        interval = self.scrub_every
        if est.enabled:
            interval = max(1, est.adaptive_interval(self.scrub_every))
        return self._step_no % interval == 0

    def _touch_pages(self) -> None:
        for b, seq in enumerate(self.slots):
            if seq is None:
                continue
            for layer in self.caches.layers.values():
                for pid in layer.slot_pages(b):
                    self.pool.touch(pid, self._step_no)

    def _invalidate_all(self) -> None:
        for layer in self.caches.layers.values():
            layer.invalidate()

    def run(self, max_steps: int = 100000) -> dict[Any, list[int]]:
        """Step until every submitted sequence finishes. Returns
        {tenant: generated tokens}."""
        steps = 0
        while (self.waiting or any(s is not None for s in self.slots)):
            if steps >= max_steps:
                raise RuntimeError(f"run() exceeded {max_steps} steps")
            self.step()
            steps += 1
        return {s.tenant: list(s.generated) for s in self.sequences}

    # -- fault injection / stats --------------------------------------------

    def inject(self, channel, key, *, tenants=None, **kw) -> int:
        """Corrupt the shared pool mid-serving (optionally only pages owned
        by `tenants`) and invalidate decoded views, so the next step's
        reads run through the decoder."""
        if not self.protected:
            return 0
        changed = self.pool.inject(channel, key, owners=tenants, **kw)
        self._invalidate_all()
        return changed

    @staticmethod
    def _slot_stores(layer, b: int):
        """The live `PooledStore`s behind slot `b` of one KV layer (empty
        for unprotected/dense layers)."""
        for name in ("k_stores", "v_stores"):
            stores = getattr(layer, name, None)
            if stores is not None and stores[b] is not None:
                yield stores[b]

    def tenant_stats(self, tenant) -> dict[str, int]:
        """Aggregated correction accounting for one tenant: banked counters
        from retired/preempted slots, live slot stores, and the pool's
        per-owner scrub attribution."""
        out = dict.fromkeys(
            ControllerStats.CORRECTION_KEYS + ("scrub_flagged",
                                               "scrub_repaired"), 0)
        for seq in self.sequences:
            if seq.tenant != tenant:
                continue
            # banked counters (stores freed on slot close — disjoint from
            # the live-store sums below by construction, see _release_slot)
            ControllerStats.add_counts(out, seq.stats)
            if seq.slot is not None:
                for layer in self.caches.layers.values():
                    for store in self._slot_stores(layer, seq.slot):
                        ControllerStats.add_counts(out, store.stats)
        if self.protected:
            ent = self.pool.scrub_by_owner.get(tenant)
            if ent:
                out["scrub_flagged"] = ent["flagged_words"]
                out["scrub_repaired"] = ent["repaired_words"]
        return out

    def publish_metrics(self, registry=None) -> None:
        """Export the engine's current accounting into a metrics registry
        (the ambient one by default): per-tenant correction triples and
        scrub attribution as gauges (idempotent across repeated publishes),
        plus the pool's `ControllerStats`. Benchmarks call this right
        before `registry.snapshot()` so per-tenant corrected counts land
        in the exported artifact."""
        reg = obs_metrics.current() if registry is None else registry
        if not getattr(reg, "enabled", False):
            return
        tenants = {}
        for s in self.sequences:
            tenants.setdefault(str(s.tenant), s.tenant)
        for label in sorted(tenants):
            for k, v in self.tenant_stats(tenants[label]).items():
                reg.gauge(f"tenant_{k}", layer="engine",
                          tenant=label).set(v)
        if self.protected:
            self.pool.stats.publish(reg, layer="pool")
            reg.gauge("pool_allocated", layer="pool").set(
                self.pool.n_allocated)
            reg.gauge("pool_available", layer="pool").set(
                self.pool.available)

    def stats(self) -> dict:
        live = sum(s is not None for s in self.slots)
        out = {"step": self._step_no, "active": live,
               "waiting": len(self.waiting),
               "done": sum(s.done for s in self.sequences),
               "preemptions": sum(s.preemptions for s in self.sequences)}
        if self.protected:
            out["pool_allocated"] = self.pool.n_allocated
            out["pool_available"] = self.pool.available
            out["scrub_rounds"] = self.pool.stats.scrub_rounds
        return out


def _code(pkv: ProtectedKVConfig):
    from repro.core import get_code
    return get_code(pkv.code_name)
