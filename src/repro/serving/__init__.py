"""Multi-tenant protected serving: continuous batching over a shared
NB-LDPC-protected page pool (`repro.serving.engine`)."""
from .engine import (BatchedDenseKV, BatchedPagedKV, EngineCaches,
                     SequenceState, ServingEngine)

__all__ = ["BatchedDenseKV", "BatchedPagedKV", "EngineCaches",
           "SequenceState", "ServingEngine"]
