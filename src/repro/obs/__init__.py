"""repro.obs — zero-dependency observability for the protected-serving
stack.

Three pillars, each with an ambient context-manager install mirroring
`repro.kernels.backend.use_policy` and each free (shared no-op singleton)
when not installed:

- **metrics** (`use_metrics`): process-global `MetricsRegistry` of labeled
  counters/gauges/histograms with dict-snapshot, JSONL, and Prometheus
  text exporters.
- **trace** (`use_tracer`): `span("engine.step")` context managers with
  optional jax sync points, exported as Chrome trace-event JSON for
  Perfetto.
- **ras** (`use_estimator`): `ErrorRateEstimator` folding scan-flag rates
  and `DecodeResult.iterations` into EWMA raw-BER / decoder-stress /
  residual-BER estimates and an `adaptive_interval()` scrub schedule.

Quickstart:

    from repro import obs

    with obs.use_metrics() as reg, obs.use_tracer() as tr, \
         obs.use_estimator() as est:
        engine.run()
    print(reg.to_prometheus())
    tr.to_chrome_trace("trace.json")
    print(est.snapshot())
"""
from repro.obs import metrics, ras, trace
from repro.obs.metrics import (MetricsRegistry, NULL_REGISTRY,
                               instrument_count, use_metrics)
from repro.obs.ras import (ErrorRateEstimator, NULL_ESTIMATOR,
                           use_estimator)
from repro.obs.trace import NULL_TRACER, Tracer, span, use_tracer

current_metrics = metrics.current
current_tracer = trace.current
current_estimator = ras.current

__all__ = [
    "metrics", "trace", "ras",
    "MetricsRegistry", "NULL_REGISTRY", "instrument_count", "use_metrics",
    "current_metrics",
    "Tracer", "NULL_TRACER", "span", "use_tracer", "current_tracer",
    "ErrorRateEstimator", "NULL_ESTIMATOR", "use_estimator",
    "current_estimator",
]
