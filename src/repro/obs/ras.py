"""RAS (reliability / availability / serviceability) estimators.

The observability layer's third pillar: turn the raw reliability signals
the stack already produces — per-page syndrome-scan flags and per-codeword
`DecodeResult.iterations` vectors — into *running estimates* that a scrub
scheduler can act on.

Estimated quantities, per region (a region is any string key — a pool
owner/tenant label, a layer name, or the default ""):

- **word flag rate** `f` — EWMA of the fraction of codewords whose
  syndrome scan flagged them dirty. A word is flagged when *any* of its n
  symbols is corrupted, so for an i.i.d. symbol channel
  ``f = 1 - (1 - ber)**n`` and the raw symbol BER is recovered as
  ``ber = 1 - (1 - f)**(1/n) ≈ -ln(1 - f)/n``.
- **decoder stress** — EWMA of FBP iterations used, normalized by the
  iteration cap. Near 0: corrections are easy (few symbol errors per
  word); near 1: words routinely hit the cap, i.e. the code is operating
  near its correction limit and residual errors are imminent. This is the
  early-warning signal the raw BER alone can't give (BER says how often
  words are dirty; stress says how *close to uncorrectable* dirty words
  are).
- **residual-BER proxy** — EWMA rate of `detect_fail` words times an
  upper-bound symbol fraction. Words the decoder failed on are the only
  ones that can leak errors downstream, so this tracks the post-correction
  (data) BER without needing ground truth.

`adaptive_interval()` maps the estimates onto a scrub period: scale a
nominal interval inversely with observed word-flag pressure (clamped), and
tighten further when decoder stress is high. `hot_regions()` ranks regions
by pressure so a sweeper can spend its page budget where flags are
actually landing (`ProtectedPagePool.scrub(prioritize=True)` consumes the
same idea per page).

Ambient installation mirrors `use_metrics`: instrumented layers call
`current().observe_scan(...)` — the default `NULL_ESTIMATOR` drops
everything at the cost of one attribute check.

All estimates are EWMAs with per-update decay ``alpha``; feeding k
observations in one call uses the exact k-step decay ``(1-alpha)**k`` so
batched and one-at-a-time feeding converge identically.
"""
from __future__ import annotations

import contextlib
import math

__all__ = ["ErrorRateEstimator", "RegionEstimate", "NULL_ESTIMATOR",
           "current", "use_estimator"]


class RegionEstimate:
    """Running EWMA state for one region (tenant / layer / pool owner)."""

    __slots__ = ("flag_rate", "stress", "fail_rate", "words_seen",
                 "words_flagged", "decode_words", "decode_fails", "_n_symbols")

    def __init__(self):
        self.flag_rate: float | None = None      # EWMA word flag rate
        self.stress: float | None = None         # EWMA iterations / cap
        self.fail_rate: float | None = None      # EWMA detect_fail rate
        self.words_seen = 0
        self.words_flagged = 0
        self.decode_words = 0
        self.decode_fails = 0
        self._n_symbols: int | None = None

    def _fold(self, prev: float | None, obs: float, alpha: float,
              k: int) -> float:
        if prev is None:
            return obs
        keep = (1.0 - alpha) ** k
        return keep * prev + (1.0 - keep) * obs

    # -- derived quantities --------------------------------------------------

    def raw_ber(self) -> float | None:
        """Per-symbol raw BER inverted from the word flag rate: a word is
        flagged iff >=1 of its n symbols flipped, so for an i.i.d. channel
        ber = 1 - (1 - f)^(1/n)."""
        if self.flag_rate is None or self._n_symbols in (None, 0):
            return None
        f = min(max(self.flag_rate, 0.0), 1.0 - 1e-12)
        return 1.0 - (1.0 - f) ** (1.0 / self._n_symbols)

    def residual_ber_proxy(self) -> float | None:
        """Upper-bound proxy for post-correction data BER: only
        detect_fail words can leak symbol errors, and at the operating
        point a failed word carries at most ~its raw symbol error
        fraction."""
        if self.fail_rate is None:
            return None
        ber = self.raw_ber()
        return self.fail_rate * (ber if ber is not None else 1.0)

    def export(self) -> dict:
        return {
            "flag_rate": self.flag_rate, "stress": self.stress,
            "fail_rate": self.fail_rate, "raw_ber": self.raw_ber(),
            "residual_ber_proxy": self.residual_ber_proxy(),
            "words_seen": self.words_seen,
            "words_flagged": self.words_flagged,
            "decode_words": self.decode_words,
            "decode_fails": self.decode_fails,
        }


class _NullEstimator:
    """Ambient default: drops all observations."""

    enabled = False

    def observe_scan(self, flagged: int, total: int, *,
                     n_symbols: int | None = None,
                     region: str = "") -> None:
        pass

    def observe_decode(self, iterations, n_iters: int, *,
                       detect_fail=None, region: str = "") -> None:
        pass

    def adaptive_interval(self, nominal: int, *, region: str = "") -> int:
        return nominal


NULL_ESTIMATOR = _NullEstimator()


class ErrorRateEstimator:
    """Folds scan flags and decode telemetry into per-region EWMA
    reliability estimates, and maps them onto a scrub schedule.

    alpha: EWMA decay per observed *word* (small alpha = long memory).
    target_flag_rate: the word flag rate the scrub schedule aims to hold;
        above it the adaptive interval shrinks proportionally, below it
        the interval relaxes back toward nominal.
    stress_threshold: normalized decoder-iteration level past which the
        interval is tightened a further 2x (words near the correction
        limit — scrub before they tip into detect_fail).
    """

    enabled = True

    def __init__(self, *, alpha: float = 0.02,
                 target_flag_rate: float = 0.05,
                 stress_threshold: float = 0.7,
                 min_scale: float = 0.1, max_scale: float = 4.0):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.target_flag_rate = target_flag_rate
        self.stress_threshold = stress_threshold
        self.min_scale = min_scale
        self.max_scale = max_scale
        self._regions: dict[str, RegionEstimate] = {}

    def region(self, region: str = "") -> RegionEstimate:
        est = self._regions.get(region)
        if est is None:
            est = self._regions[region] = RegionEstimate()
        return est

    # -- observation feeds ---------------------------------------------------

    def observe_scan(self, flagged: int, total: int, *,
                     n_symbols: int | None = None,
                     region: str = "") -> None:
        """Feed one syndrome-scan outcome: `flagged` of `total` codewords
        were dirty. `n_symbols` (codeword length n) enables raw-BER
        inversion."""
        total = int(total)
        if total <= 0:
            return
        flagged = int(flagged)
        est = self.region(region)
        if n_symbols:
            est._n_symbols = int(n_symbols)
        est.words_seen += total
        est.words_flagged += flagged
        est.flag_rate = est._fold(est.flag_rate, flagged / total,
                                  self.alpha, total)

    def observe_decode(self, iterations, n_iters: int, *,
                       detect_fail=None, region: str = "") -> None:
        """Feed a decode outcome: `iterations` is a per-codeword iteration
        count (scalar, sequence, or numpy array — `DecodeResult.iterations`
        feeds straight in), `n_iters` the decoder's cap, `detect_fail` an
        optional parallel bool vector."""
        vals = _as_float_list(iterations)
        if not vals or n_iters <= 0:
            return
        est = self.region(region)
        k = len(vals)
        est.decode_words += k
        mean_stress = min(sum(vals) / (k * n_iters), 1.0)
        est.stress = est._fold(est.stress, mean_stress, self.alpha, k)
        if detect_fail is not None:
            fails = _as_float_list(detect_fail)
            n_fail = sum(1.0 for v in fails if v)
            est.decode_fails += int(n_fail)
            est.fail_rate = est._fold(est.fail_rate, n_fail / k,
                                      self.alpha, k)

    # -- scheduling ----------------------------------------------------------

    def pressure(self, region: str = "") -> float:
        """Scalar scrub pressure >= 0: observed flag rate over target,
        doubled when decoder stress crosses the threshold. 1.0 = on
        target; >1 = scrub more; <1 = can relax."""
        est = self._regions.get(region)
        if est is None or est.flag_rate is None:
            return 1.0
        pr = est.flag_rate / max(self.target_flag_rate, 1e-12)
        if est.stress is not None and est.stress >= self.stress_threshold:
            pr *= 2.0
        return pr

    def adaptive_interval(self, nominal: int, *, region: str = "") -> int:
        """Scrub period (steps/seconds — caller's unit) scaled inversely
        with pressure and clamped to [min_scale, max_scale] x nominal.
        With no observations yet, returns `nominal` unchanged."""
        nominal = int(nominal)
        if nominal <= 0:
            return nominal
        pr = self.pressure(region)
        scale = 1.0 / max(pr, 1e-12)
        scale = min(max(scale, self.min_scale), self.max_scale)
        return max(1, int(round(nominal * scale)))

    def hot_regions(self, top: int | None = None
                    ) -> list[tuple[str, float]]:
        """Regions ranked by scrub pressure, hottest first."""
        ranked = sorted(((r, self.pressure(r)) for r in self._regions),
                        key=lambda kv: (-kv[1], kv[0]))
        return ranked[:top] if top is not None else ranked

    def snapshot(self) -> dict:
        """{region: estimates} — JSON-stable (None for not-yet-observed)."""
        return {r: est.export()
                for r, est in sorted(self._regions.items())}

    def publish(self, registry, *, layer: str = "ras") -> None:
        """Push current estimates into a `MetricsRegistry` as gauges."""
        if registry is None or not getattr(registry, "enabled", False):
            return
        for region, est in self._regions.items():
            for field in ("flag_rate", "stress", "fail_rate"):
                v = getattr(est, field)
                if v is not None:
                    registry.gauge(f"ras_{field}", layer=layer,
                                   region=region).set(v)
            ber = est.raw_ber()
            if ber is not None:
                registry.gauge("ras_raw_ber", layer=layer,
                               region=region).set(ber)
            res = est.residual_ber_proxy()
            if res is not None:
                registry.gauge("ras_residual_ber_proxy", layer=layer,
                               region=region).set(res)


def _as_float_list(x) -> list[float]:
    """Coerce scalar / sequence / numpy array to a flat float list without
    importing numpy (works on anything iterable of numbers)."""
    if x is None:
        return []
    tolist = getattr(x, "tolist", None)
    if tolist is not None:
        x = tolist()
    if isinstance(x, (int, float, bool)):
        return [float(x)]
    try:
        out: list[float] = []
        for v in x:
            if isinstance(v, (list, tuple)):
                out.extend(float(u) for u in v)
            else:
                out.append(float(v))
        return out
    except TypeError:
        return [float(x)]


def expected_flag_rate(channel_T, n_symbols: int) -> float:
    """Closed-form word flag rate for an i.i.d. `LevelTransition` matrix:
    per-symbol error prob eps = 1 - mean(diag(T)) (uniform level prior),
    word flag rate = 1 - (1 - eps)^n. Test/calibration helper."""
    diag = [channel_T[i][i] for i in range(len(channel_T))]
    eps = 1.0 - sum(float(d) for d in diag) / len(diag)
    return 1.0 - (1.0 - eps) ** n_symbols


def invert_flag_rate(flag_rate: float, n_symbols: int) -> float:
    """ber = 1 - (1-f)^(1/n), the small-f limit of -ln(1-f)/n."""
    f = min(max(flag_rate, 0.0), 1.0 - 1e-12)
    return 1.0 - math.exp(math.log1p(-f) / n_symbols)


# ---------------------------------------------------------------------------
# ambient estimator
# ---------------------------------------------------------------------------

_current = NULL_ESTIMATOR


def current():
    return _current


@contextlib.contextmanager
def use_estimator(estimator: ErrorRateEstimator | None = None):
    """Install `estimator` as the ambient RAS sink for the block (a fresh
    `ErrorRateEstimator` when called with None). Yields the estimator."""
    global _current
    est = ErrorRateEstimator() if estimator is None else estimator
    prev = _current
    _current = est
    try:
        yield est
    finally:
        _current = prev
