"""Span tracing: lightweight context-manager spans exported as Chrome
trace-event JSON (loadable in Perfetto / chrome://tracing).

The observability layer's second pillar. A `Tracer` collects complete
("ph": "X") trace events; `span("engine.step", step=7)` times a block and
records one event with its keyword arguments as event args, so the
prefill / decode / scrub / preemption interleaving of the serving engine
becomes a visible timeline per step and per tenant.

Ambient installation mirrors `use_metrics` / `use_policy`:

    tracer = Tracer()
    with use_tracer(tracer):
        engine.run()
    tracer.to_chrome_trace("trace.json")       # open in ui.perfetto.dev

Disabled (the default), `span(...)` returns a shared no-op context
manager — the hot loop pays one ambient lookup and nothing else.

Two jax-aware extras:

- `span(..., sync=x)` calls `jax.block_until_ready(x)` before closing the
  span, so the recorded duration covers device completion, not just
  dispatch (async dispatch otherwise attributes device time to whichever
  later span happens to block);
- `Tracer(jax_profiler=True)` additionally wraps every span in
  `jax.profiler.TraceAnnotation`, so the same span names line up inside a
  `jax.profiler.trace(...)` capture when one is active.

Nesting is tracked per thread: sibling and child spans nest correctly in
the rendered flame because their timestamps nest; `depth` rides in the
event args for programmatic consumers (tests assert ordering with it).
"""
from __future__ import annotations

import contextlib
import json
import threading
import time

__all__ = ["Tracer", "NULL_TRACER", "current", "use_tracer", "span"]


class _Span:
    """One in-flight span (context manager recorded on exit)."""

    __slots__ = ("tracer", "name", "args", "sync", "t0", "depth")

    def __init__(self, tracer: "Tracer", name: str, sync, args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.sync = sync
        self.t0 = 0
        self.depth = 0

    def __enter__(self):
        tl = self.tracer._tls
        self.depth = getattr(tl, "depth", 0)
        tl.depth = self.depth + 1
        self.tracer._enter_profiler(self.name)
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self.sync is not None:
            import jax
            jax.block_until_ready(self.sync)
        t1 = time.perf_counter_ns()
        self.tracer._exit_profiler()
        self.tracer._tls.depth = self.depth
        self.tracer._record(self.name, self.t0, t1, self.depth, self.args)
        return False

    def set(self, **args) -> None:
        """Attach/overwrite event args from inside the span."""
        self.args.update(args)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **args) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _NullTracer:
    enabled = False

    def span(self, name: str, *, sync=None, **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **args) -> None:
        pass

    def events(self) -> list:
        return []


NULL_TRACER = _NullTracer()


class Tracer:
    """Collects Chrome trace events. `max_events` bounds memory (oldest
    events are dropped with a `truncated` marker rather than growing
    without bound under a long-running engine)."""

    enabled = True

    def __init__(self, *, pid: int = 0, max_events: int = 200_000,
                 jax_profiler: bool = False):
        self.pid = pid
        self.max_events = max_events
        self.jax_profiler = jax_profiler
        self._events: list[dict] = []
        self._dropped = 0
        self._epoch_ns = time.perf_counter_ns()
        self._tls = threading.local()
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------------

    def span(self, name: str, *, sync=None, **args) -> _Span:
        """Context manager timing a block; `sync` (any jax pytree) is
        blocked on before the span closes so device work is billed to the
        span that launched it."""
        return _Span(self, name, sync, args)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker event (preemptions, injections)."""
        ts = (time.perf_counter_ns() - self._epoch_ns) / 1e3
        self._append({"name": name, "ph": "i", "s": "t", "ts": ts,
                      "pid": self.pid, "tid": threading.get_ident() % 2**31,
                      "args": args})

    def _record(self, name, t0_ns, t1_ns, depth, args) -> None:
        ev_args = dict(args)
        ev_args["depth"] = depth
        self._append({
            "name": name, "ph": "X",
            "ts": (t0_ns - self._epoch_ns) / 1e3,        # microseconds
            "dur": (t1_ns - t0_ns) / 1e3,
            "pid": self.pid, "tid": threading.get_ident() % 2**31,
            "args": ev_args})

    def _append(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self._events.pop(0)
                self._dropped += 1
            self._events.append(ev)

    def _enter_profiler(self, name: str) -> None:
        if not self.jax_profiler:
            return
        try:
            import jax.profiler
            ann = jax.profiler.TraceAnnotation(name)
            ann.__enter__()
            stack = getattr(self._tls, "annotations", None)
            if stack is None:
                stack = self._tls.annotations = []
            stack.append(ann)
        except Exception:
            self.jax_profiler = False       # bridge unavailable: degrade

    def _exit_profiler(self) -> None:
        if not self.jax_profiler:
            return
        stack = getattr(self._tls, "annotations", None)
        if stack:
            stack.pop().__exit__(None, None, None)

    # -- export --------------------------------------------------------------

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def spans(self, name: str | None = None) -> list[dict]:
        """Complete ("X") events, optionally filtered by name."""
        return [e for e in self.events()
                if e["ph"] == "X" and (name is None or e["name"] == name)]

    def to_chrome_trace(self, path: str | None = None) -> dict:
        """The Chrome trace-event JSON object; written to `path` when
        given. Load with chrome://tracing or ui.perfetto.dev."""
        doc = {"traceEvents": self.events(), "displayTimeUnit": "ms",
               "otherData": {"dropped_events": self._dropped}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


# ---------------------------------------------------------------------------
# ambient tracer
# ---------------------------------------------------------------------------

_current = NULL_TRACER


def current():
    return _current


@contextlib.contextmanager
def use_tracer(tracer: Tracer | None = None):
    """Install `tracer` as the ambient span sink for the block (a fresh
    `Tracer` when called with None). Yields the tracer."""
    global _current
    tr = Tracer() if tracer is None else tracer
    prev = _current
    _current = tr
    try:
        yield tr
    finally:
        _current = prev


def span(name: str, *, sync=None, **args):
    """`with span("engine.step", step=i):` — records on the ambient tracer,
    free (a shared no-op) when tracing is disabled."""
    t = _current
    if not t.enabled:
        return _NULL_SPAN
    return t.span(name, sync=sync, **args)
