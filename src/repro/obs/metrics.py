"""Process-global metrics registry: labeled counters / gauges / histograms.

The observability layer's first pillar. Design constraints, in order:

1. **Free when off.** The ambient registry defaults to `NULL_REGISTRY`
   (`enabled == False`), whose instrument factories return shared no-op
   singletons — a disabled hot loop allocates *zero* metric objects (the
   serving engine additionally guards its instrumentation behind one
   `registry.enabled` check per step, so the off path is a single attribute
   read). `tests/test_obs.py` pins this with an allocation counter.
2. **Ambient, like `use_policy`.** `use_metrics(registry)` installs a
   registry for a `with` block (mirroring `repro.kernels.backend.use_policy`)
   so benchmarks and the serving engine never thread a registry argument
   through every layer; `current()` reads the ambient one.
3. **Bounded label cardinality.** Instruments are keyed by
   (name, sorted label items). Past `max_series` distinct label sets per
   metric name, new sets fold into one `{"overflow": "true"}` series (with
   a single warning) instead of growing without bound — a tenant-id label
   on a million-user fleet must not OOM the registry.

Exporters: `snapshot()` (plain dict, JSON-stable), `append_jsonl(path)`
(one snapshot per line — the fleet-scrub daemon's log format), and
`to_prometheus()` (Prometheus text exposition format, so a scrape endpoint
only has to serve the string).

No dependencies beyond the standard library.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
import warnings
from collections.abc import Iterable

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "NULL_REGISTRY", "current", "use_metrics", "instrument_count"]

# default histogram buckets: latencies in seconds (spans, step times) and
# small rates both land usefully on a log-ish grid
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

# module-wide count of real instrument objects ever constructed; the
# disabled-path test asserts a metrics-off serving loop leaves it unchanged
_n_instruments = 0


def instrument_count() -> int:
    """Total real (non-null) instruments constructed in this process."""
    return _n_instruments


def _bump():
    global _n_instruments
    _n_instruments += 1


LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing value."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        _bump()
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increments must be >= 0, got {amount}")
        self.value += amount

    def export(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Point-in-time value (set / add)."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self):
        _bump()
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def add(self, amount: float) -> None:
        self.value += amount

    def export(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Fixed-bucket histogram with sum/count (Prometheus-style cumulative
    buckets on export)."""

    kind = "histogram"
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Iterable[float] = DEFAULT_BUCKETS):
        _bump()
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)   # +inf tail
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        value = float(value)
        self.sum += value
        self.count += 1
        for i, edge in enumerate(self.buckets):
            if value <= edge:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def export(self) -> dict:
        cum, acc = [], 0
        for c in self.counts:
            acc += c
            cum.append(acc)
        return {"sum": self.sum, "count": self.count,
                "buckets": {("+Inf" if i == len(self.buckets)
                             else repr(self.buckets[i])): cum[i]
                            for i in range(len(self.counts))}}


class _NullInstrument:
    """Shared do-nothing instrument returned by the null registry."""

    __slots__ = ()

    def inc(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def add(self, amount: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class _NullRegistry:
    """The ambient default: everything is a no-op, nothing is allocated."""

    enabled = False

    def counter(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, *, buckets=DEFAULT_BUCKETS,
                  **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> dict:
        return {}


NULL_REGISTRY = _NullRegistry()


class MetricsRegistry:
    """Labeled instrument store. One registry per process (or per bench
    phase); instruments are created on first use and shared thereafter."""

    enabled = True

    def __init__(self, *, max_series: int = 512):
        if max_series <= 0:
            raise ValueError(f"max_series must be positive, got {max_series}")
        self.max_series = max_series
        # name -> {label_key -> instrument}; kinds tracked per name so a
        # counter name can't silently come back as a gauge
        self._series: dict[str, dict[LabelKey, object]] = {}
        self._kinds: dict[str, str] = {}
        self._overflowed: set = set()
        self._lock = threading.Lock()

    # -- instrument factories ------------------------------------------------

    def _get(self, kind: str, name: str, labels: dict[str, str], make):
        key = _label_key(labels)
        with self._lock:
            series = self._series.setdefault(name, {})
            prev_kind = self._kinds.setdefault(name, kind)
            if prev_kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {prev_kind}, "
                    f"requested as {kind}")
            inst = series.get(key)
            if inst is None:
                if len(series) >= self.max_series:
                    # cardinality guard: fold the overflow into one series
                    if name not in self._overflowed:
                        self._overflowed.add(name)
                        warnings.warn(
                            f"metric {name!r} exceeded max_series="
                            f"{self.max_series} label sets; folding further "
                            "label sets into the overflow series",
                            RuntimeWarning, stacklevel=3)
                    key = _label_key({"overflow": "true"})
                    inst = series.get(key)
                    if inst is None:
                        inst = series[key] = make()
                else:
                    inst = series[key] = make()
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, *, buckets=DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(buckets))

    # -- exporters -----------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-dict export: {name: {"kind": ..., "series": [{"labels":
        {...}, ...instrument fields...}]}} — JSON round-trip stable."""
        out: dict = {}
        with self._lock:
            for name, series in sorted(self._series.items()):
                rows: list[dict] = []
                for key in sorted(series):
                    row = {"labels": dict(key)}
                    row.update(series[key].export())
                    rows.append(row)
                out[name] = {"kind": self._kinds[name], "series": rows}
        return out

    def append_jsonl(self, path: str, *, meta: dict | None = None) -> None:
        """Append one snapshot line: {"ts": ..., "metrics": {...}, **meta}."""
        rec = {"ts": time.time(), "metrics": self.snapshot()}
        if meta:
            rec.update(meta)
        with open(path, "a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (counters get a `_total`
        suffix; histograms expand to `_bucket{le=...}` / `_sum` /
        `_count`)."""
        lines: list[str] = []
        snap = self.snapshot()
        for name, ent in snap.items():
            kind = ent["kind"]
            pname = f"{name}_total" if (kind == "counter"
                                        and not name.endswith("_total")) \
                else name
            lines.append(f"# TYPE {pname} {kind}")
            for row in ent["series"]:
                lab = row["labels"]
                if kind == "histogram":
                    for le, c in row["buckets"].items():
                        lines.append(f"{pname}_bucket"
                                     f"{_prom_labels({**lab, 'le': le})} {c}")
                    lines.append(f"{pname}_sum{_prom_labels(lab)} "
                                 f"{row['sum']}")
                    lines.append(f"{pname}_count{_prom_labels(lab)} "
                                 f"{row['count']}")
                else:
                    lines.append(f"{pname}{_prom_labels(lab)} "
                                 f"{row['value']}")
        return "\n".join(lines) + "\n"

    @staticmethod
    def value(snapshot: dict, name: str, **labels) -> float | None:
        """Pull one series' value out of a `snapshot()` dict (test/bench
        convenience; None when the series doesn't exist)."""
        ent = snapshot.get(name)
        if not ent:
            return None
        want = dict(_label_key(labels))
        for row in ent["series"]:
            if row["labels"] == want:
                return row.get("value", row.get("sum"))
        return None


def _prom_labels(labels: dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


# ---------------------------------------------------------------------------
# ambient registry (mirrors repro.kernels.backend.use_policy)
# ---------------------------------------------------------------------------

_current = NULL_REGISTRY


def current():
    """The ambient registry (`NULL_REGISTRY` unless `use_metrics` is
    active). Hot paths read `.enabled` once and skip all instrumentation
    when False."""
    return _current


@contextlib.contextmanager
def use_metrics(registry: MetricsRegistry | None = None):
    """Install `registry` as the ambient metrics sink for the block (a
    fresh `MetricsRegistry` when called with None). Yields the registry."""
    global _current
    reg = MetricsRegistry() if registry is None else registry
    prev = _current
    _current = reg
    try:
        yield reg
    finally:
        _current = prev
