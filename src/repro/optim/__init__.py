"""Optimizers and schedules (self-contained, optax-style API).

- `adamw`: fp32 moments; the default.
- `adafactor`: factored second moment — the memory-frugal choice for the
  >=90B assigned architectures (DESIGN.md §5); optional (unfactored) momentum.
- `warmup_cosine`: LR schedule.
- `clip_by_global_norm` composes into both via the `clip` argument.

A transform is a pair (init(params) -> state, update(grads, state, params)
-> (new_params, new_state)). Updates are applied inside — the train step
stays one call.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Transform(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1) -> Callable:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)
    return sched


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_grads(grads, max_norm: float):
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          clip: float = 1.0) -> Transform:
    sched = lr if callable(lr) else constant_lr(lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": zeros,
                "v": jax.tree.map(jnp.zeros_like, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if clip:
            grads, gn = clip_grads(grads, clip)
        else:
            gn = _global_norm(grads)
        step = state["step"] + 1
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p - lr_t * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}, gn

    return Transform(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------


def adafactor(lr: Callable | float, *, decay: float = 0.8, eps: float = 1e-30,
              clip: float = 1.0, momentum: float = 0.0,
              weight_decay: float = 0.0) -> Transform:
    sched = lr if callable(lr) else constant_lr(lr)

    def factored(p):
        return p.ndim >= 2

    def init(params):
        def st(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        state = {"f": jax.tree.map(st, params), "step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["m"] = jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
        return state

    def update(grads, state, params):
        if clip:
            grads, gn = clip_grads(grads, clip)
        else:
            gn = _global_norm(grads)
        step = state["step"] + 1
        lr_t = sched(step)
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(p, g, f):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                vr = beta * f["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * f["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None], eps))
                # zero-grad leaves (e.g. unrouted experts): rsqrt(0) = inf and
                # 0 * inf = NaN -> clamp the denominator
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nf = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                nf = {"v": v}
            # update clipping (RMS <= 1), per the paper
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p - lr_t * u).astype(p.dtype), nf

        isdict = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        out = jax.tree.map(upd, params, grads, state["f"], is_leaf=None)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_f = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"f": new_f, "step": step}
        if momentum:
            m = jax.tree.map(lambda m, p0, p1: momentum * m + (p1 - p0),
                             state["m"], params, new_p)
            new_p = jax.tree.map(lambda p0, mm: (p0 + mm).astype(p0.dtype),
                                 params, m)
            new_state["m"] = m
        return new_p, new_state, gn

    return Transform(init, update)


def make_optimizer(name: str, lr, **kw) -> Transform:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise KeyError(f"unknown optimizer {name!r}")
