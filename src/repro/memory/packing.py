"""Byte/tensor <-> GF(p) symbol packing shared by both protected-store
backends.

The host backend (`repro.memory.array.ProtectedMemoryArray`, numpy) and the
device-resident backend (`repro.memory.paged.PagedProtectedStore`, jax) pack
payloads the same way: bytes are symbolized as base-p digits — ceil(log_p 256)
digits per byte, little-endian — and the digit stream is chunked into
(k,)-symbol info words. Keeping one definition here means pages encoded on
device decode bit-exactly against host-encoded checkpoints and vice versa.

`symbolize_bytes`/`desymbolize_bytes` are the numpy pair (checkpoint write
path); `symbolize_u8`/`desymbolize_u8` are the jittable jax pair the paged
store uses to quantize live tensors into cell levels without leaving the
device.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

__all__ = ["digits_per_byte", "symbolize_bytes", "desymbolize_bytes",
           "symbolize_u8", "desymbolize_u8"]


def digits_per_byte(p: int) -> int:
    """Base-p digits needed to hold one byte: ceil(log_p 256)."""
    return math.ceil(8.0 / math.log2(p))


def symbolize_bytes(raw: bytes | np.ndarray, p: int) -> np.ndarray:
    """bytes -> flat array of base-p digits (little-endian per byte)."""
    b = np.frombuffer(raw, np.uint8).astype(np.int64) \
        if not isinstance(raw, np.ndarray) else raw.astype(np.int64)
    D = digits_per_byte(p)
    return np.stack([(b // p ** i) % p for i in range(D)], -1).reshape(-1)


def desymbolize_bytes(syms: np.ndarray, nbytes: int, p: int) -> bytes:
    """Inverse of `symbolize_bytes`. Digits are clipped into the field and
    the value into a byte, so corrupted-but-uncorrected symbols degrade to
    wrong bytes instead of crashing."""
    D = digits_per_byte(p)
    d = np.clip(syms[:nbytes * D].reshape(-1, D).astype(np.int64), 0, p - 1)
    vals = sum(d[:, i] * p ** i for i in range(D)) % 256
    return vals.astype(np.uint8).tobytes()


def symbolize_u8(vals: jnp.ndarray, p: int) -> jnp.ndarray:
    """Device-side symbolization: integer byte values in [0, 256) of any
    shape -> (..., D) base-p digits (same digit order as `symbolize_bytes`,
    so host and device packings interoperate)."""
    D = digits_per_byte(p)
    v = vals.astype(jnp.int32)
    return jnp.stack([(v // p ** i) % p for i in range(D)], axis=-1)


def desymbolize_u8(digits: jnp.ndarray, p: int) -> jnp.ndarray:
    """Device-side inverse: (..., D) base-p digits -> (...,) byte values in
    [0, 256). Digits are clipped into the field first, mirroring the host
    pair's degrade-don't-crash contract for uncorrected symbols."""
    D = digits_per_byte(p)
    d = jnp.clip(digits.astype(jnp.int32), 0, p - 1)
    val = sum(d[..., i] * p ** i for i in range(D))
    return val % 256
