"""Coalescing repair pipeline: cross-page flagged-word batching.

The scan -> gated-decode split (the paper's efficiency argument) only pays
off if sparse flags stay cheap to *repair*: at raw BER 1e-3 a page of 256
words carries a handful of flagged rows, and padding each page's flags to a
full `chunk_size` FBP dispatch — then syncing before the next page — makes
decode dispatch, not the scan, the sweep bottleneck (the dataflow
interruption the high-throughput memristive-ECC line warns about).

`RepairQueue` decouples flag discovery from repair:

- **accumulate** — `enqueue()` collects flagged (b, n) level-word batches
  from anywhere (controller pages, paged-store pages, every tenant of a
  shared pool), each with a writeback closure, an owner label for
  per-tenant attribution, and (store, page, rows) provenance;
- **bucketed decode** — `drain()` concatenates everything queued and runs
  it through power-of-two-bucketed decode executables (8/16/.../chunk_size
  rows, the `np_bucket` idiom from `attend_protected`), so 3 flagged words
  pay a ~8-row FBP instead of a `chunk_size`-row one, while dense batches
  still use the full-width executable. Executables are cached process-wide
  per (code, decode params, rows), and a drain prefers padding up to an
  already-warm bucket over compiling its exact size — FBP compiles cost
  seconds on CPU, pad rows cost microseconds;
- **one sync per drain** — every bucket decode is dispatched
  asynchronously, then a single `jax.device_get` resolves the whole train;
  repairs scatter back through the writebacks afterward. FBP is row-
  independent (per-codeword early exit), so decoding rows in a coalesced
  batch is bit-exact with decoding them per page.

On accelerator backends the bucket executables donate their input buffer
(the padded flagged-row batch is dead after dispatch); CPU jit ignores
donation, so it is gated off there to avoid the warning.

Queue depth, pad-waste ratio, and drain latency feed `repro.obs` metrics;
decode iteration vectors feed the RAS estimator per owner region — all
no-ops unless the ambient telemetry is installed.
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.construction import LDPCCode
from repro.core.decode import decode_integers
from repro.kernels.ops import np_bucket
from repro.obs import metrics as obs_metrics
from repro.obs import ras as obs_ras

__all__ = ["RepairQueue", "bucket_sizes"]


def bucket_sizes(chunk_size: int, min_bucket: int = 8) -> list[int]:
    """The decode-executable row counts a queue of `chunk_size` may build:
    powers of two from `min_bucket` up, capped by (and always including)
    `chunk_size` itself."""
    sizes = []
    b = min(min_bucket, chunk_size)
    while b < chunk_size:
        sizes.append(b)
        b *= 2
    sizes.append(chunk_size)
    return sizes


# process-wide decode-executable cache, keyed by (decode config, bucket
# rows): every queue on the same code/params shares warm executables, so a
# bench's warm run (or a sibling tenant's sweep) pays the compile, not the
# timed region. Executables close over their code object, so the id() key
# can never be reused while its entry lives.
_DECODER_CACHE: dict[tuple, dict[int, object]] = {}


@dataclasses.dataclass
class _Entry:
    """One enqueued batch of flagged rows awaiting the next drain."""

    words: object               # (rows, n) flagged level-words (np or jnp)
    writeback: Callable         # (symbols (rows, n) int64, ok (rows,)) -> None
    owner: object               # tenant label for per-owner attribution
    provenance: tuple           # e.g. ("pool", page_id, row_indices)
    rows: int


class RepairQueue:
    """Accumulates flagged codeword rows across pages/stores/tenants and
    drains them through bucketed decode executables with one host sync."""

    def __init__(self, code: LDPCCode, *, chunk_size: int = 256,
                 min_bucket: int = 8, n_iters: int = 10,
                 damping: float = 0.3, llv_scale: float = 4.0,
                 llv_mode: str = "manhattan", use_sharded: bool = False,
                 donate: bool | None = None):
        self.code = code
        self.chunk_size = int(chunk_size)
        self.min_bucket = min(int(min_bucket), self.chunk_size)
        self.n_iters = n_iters
        self.damping = damping
        self.llv_scale = llv_scale
        self.llv_mode = llv_mode
        self.use_sharded = use_sharded
        # donating the padded input buffer lets XLA reuse it for the decode
        # workspace on TPU/GPU; CPU jit warns-and-ignores, so gate it off
        self.donate = (jax.default_backend() != "cpu" if donate is None
                       else donate)
        self._decoders = _DECODER_CACHE.setdefault(
            (id(code), n_iters, damping, llv_scale, llv_mode, use_sharded,
             self.donate), {})
        self._entries: list[_Entry] = []
        self._pending = 0
        # lifetime totals (exposed so benches/tests can read pad waste
        # without the metrics registry installed)
        self.drains = 0
        self.total_rows = 0
        self.total_pad_rows = 0
        self.total_repaired = 0
        self.total_failed = 0

    # -- bucketed executables -----------------------------------------------

    def bucket_for(self, rows: int) -> int:
        """Smallest decode bucket that fits `rows` (power of two, floor
        `min_bucket`, cap `chunk_size`)."""
        return min(self.chunk_size, max(self.min_bucket, np_bucket(rows)))

    def _dispatch_size(self, rows: int) -> int:
        """Bucket to actually dispatch `rows` on: the ideal `bucket_for`
        size if it is already compiled (or nothing bigger is), else the
        smallest compiled bucket that fits. Padding a drain up to a warm
        executable costs microseconds of extra FBP rows; compiling a new
        bucket costs ~seconds on CPU — never pay a compile a warm bucket
        could absorb."""
        want = self.bucket_for(rows)
        if want in self._decoders:
            return want
        compiled = [s for s in self._decoders
                    if want < s <= self.chunk_size]
        return min(compiled) if compiled else want

    def _decoder(self, size: int):
        """One cached fixed-shape (size, n) decode executable per bucket."""
        fn = self._decoders.get(size)
        if fn is not None:
            return fn
        code = self.code
        kw = dict(n_iters=self.n_iters, damping=self.damping,
                  llv_scale=self.llv_scale, llv_mode=self.llv_mode,
                  early_exit=True)
        run = None
        if self.use_sharded:
            from repro.core.protected import np_prod_mesh
            from repro.distributed.sharding import data_mesh, decode_sharded
            mesh = data_mesh()
            if size % np_prod_mesh(mesh) == 0:
                def run(y):
                    return decode_sharded(code, y, mesh=mesh, **kw)
        if run is None:
            def run(y):
                return decode_integers(code, y, **kw)
        donate = self.donate and not self.use_sharded
        fn = jax.jit(run, donate_argnums=(0,)) if donate else jax.jit(run)
        self._decoders[size] = fn
        return fn

    def _pad(self, words, size: int):
        """Zero-pad (b, n) rows up to the bucket's fixed row count (zero
        words are valid codewords: unflagged, converge immediately). Works
        on host or device arrays without forcing a transfer."""
        xp = np if isinstance(words, np.ndarray) else jnp
        words = words.astype(xp.int32)
        b = words.shape[0]
        if b < size:
            words = xp.concatenate(
                [words, xp.zeros((size - b, self.code.n), xp.int32)])
        return words

    def decode_batch(self, words):
        """Decode (B, n) flagged level-words through the bucketed
        executables: full `chunk_size` chunks plus a bucketed tail, every
        dispatch asynchronous, then ONE host sync for the whole train.
        Returns (symbols (B, n) int64, fail (B,), iterations (B,) | None,
        pad_rows)."""
        B = int(words.shape[0])
        if B == 0:
            return (np.zeros((0, self.code.n), np.int64),
                    np.zeros(0, bool), None, 0)
        cs = self.chunk_size
        launched = []
        pad_rows = 0
        for lo in range(0, B, cs):
            chunk = words[lo:lo + cs]
            b = int(chunk.shape[0])
            size = self._dispatch_size(b)
            pad_rows += size - b
            _y, res = self._decoder(size)(jnp.asarray(self._pad(chunk, size)))
            launched.append((res, b))
        # the drain's single sync: every bucket decode is already in flight
        pulled = jax.device_get(
            [(r.symbols, r.detect_fail, getattr(r, "iterations", None))
             for r, _ in launched])
        syms = np.empty((B, self.code.n), np.int64)
        fail = np.empty(B, bool)
        have_iters = all(t[2] is not None for t in pulled)
        iters = np.empty(B, np.int64) if have_iters else None
        lo = 0
        for (s, f, it), (_res, b) in zip(pulled, launched, strict=True):
            syms[lo:lo + b] = s[:b]
            fail[lo:lo + b] = f[:b]
            if have_iters:
                iters[lo:lo + b] = it[:b]
            lo += b
        self.total_rows += B
        self.total_pad_rows += pad_rows
        return syms, fail, iters, pad_rows

    # -- queue surface ------------------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def pending_words(self) -> int:
        return self._pending

    def enqueue(self, words, writeback, *, owner=None,
                provenance: tuple = ()) -> None:
        """Queue (rows, n) flagged level-words for the next drain.
        `writeback(symbols, ok)` is called with the decoded (rows, n) int64
        symbols and the (rows,) repaired mask; `owner` labels the rows for
        per-tenant attribution in the drain report."""
        rows = int(words.shape[0])
        if rows == 0:
            return
        self._entries.append(
            _Entry(words, writeback, owner, tuple(provenance), rows))
        self._pending += rows

    def drain(self) -> dict:
        """Decode everything queued as one coalesced bucketed dispatch
        train (single host sync), scatter repairs through each entry's
        writeback, and report words / repaired / pad waste / by_owner."""
        entries, self._entries = self._entries, []
        pending, self._pending = self._pending, 0
        if not entries:
            return {"entries": 0, "words": 0, "repaired": 0, "failed": 0,
                    "pad_rows": 0, "dispatch_rows": 0, "pad_waste": 0.0,
                    "by_owner": {}, "seconds": 0.0}
        t0 = time.perf_counter()
        if len(entries) == 1:
            batch = entries[0].words
        elif all(isinstance(e.words, np.ndarray) for e in entries):
            batch = np.concatenate([e.words for e in entries])
        else:
            batch = jnp.concatenate(
                [jnp.asarray(e.words, jnp.int32) for e in entries])
        syms, fail, iters, pad_rows = self.decode_batch(batch)
        est = obs_ras.current()
        by_owner: dict[object, dict] = {}
        lo = 0
        for e in entries:
            s = syms[lo:lo + e.rows]
            f = fail[lo:lo + e.rows]
            ok = ~f
            e.writeback(s, ok)
            ent = by_owner.setdefault(
                e.owner, {"flagged_words": 0, "repaired_words": 0})
            ent["flagged_words"] += e.rows
            ent["repaired_words"] += int(ok.sum())
            if est.enabled and iters is not None:
                est.observe_decode(iters[lo:lo + e.rows], self.n_iters,
                                   detect_fail=f,
                                   region=str(e.owner)
                                   if e.owner is not None else "")
            lo += e.rows
        dt = time.perf_counter() - t0
        repaired = int((~fail).sum())
        failed = pending - repaired
        self.drains += 1
        self.total_repaired += repaired
        self.total_failed += failed
        reg = obs_metrics.current()
        if reg.enabled:
            reg.histogram("repair_queue_depth", layer="repair").observe(
                pending)
            reg.histogram("repair_drain_seconds", layer="repair").observe(dt)
            reg.counter("repair_drains", layer="repair").inc()
            reg.counter("repair_rows", layer="repair").inc(pending)
            reg.counter("repair_pad_rows", layer="repair").inc(pad_rows)
            reg.counter("repair_repaired", layer="repair").inc(repaired)
            reg.counter("repair_uncorrectable", layer="repair").inc(failed)
        dispatch_rows = pending + pad_rows
        return {"entries": len(entries), "words": pending,
                "repaired": repaired, "failed": failed,
                "pad_rows": pad_rows, "dispatch_rows": dispatch_rows,
                "pad_waste": pad_rows / dispatch_rows if dispatch_rows
                else 0.0,
                "by_owner": by_owner, "seconds": dt}

    @property
    def pad_waste(self) -> float:
        """Lifetime fraction of dispatched decode rows that were padding."""
        total = self.total_rows + self.total_pad_rows
        return self.total_pad_rows / total if total else 0.0
