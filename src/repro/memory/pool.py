"""Shared protected page pool + block allocator for multi-tenant serving.

The single-tenant `PagedProtectedStore` owns grow-only pages, which is right
for one sequence but wasteful across many: every tenant compiles nothing new
(the executables are shape-keyed on `(page_words, n)`), yet each holds
private device buffers it may barely fill, and nothing can reclaim a retired
tenant's pages. This module supplies the vLLM-style layer underneath:

- **`ProtectedPagePool`** — a fixed capacity of `(page_words, n)` GF-level
  pages with a free list, reference counts (so prefix-shared sequences can
  alias blocks), per-page owner labels and last-touch stamps (LRU / cold
  selection), and an incremental `scrub()` that sweeps cold pages with the
  same fused scan -> gated decode -> writeback path the stores use,
  attributing repairs to the owning tenant. The sweep order is round-robin
  by default, or flag-EWMA-prioritized (`prioritize=True`) so a small page
  budget lands on hot-flagging pages — the estimator-driven schedule
  `repro.serving.ServingEngine` drives via
  `repro.obs.ErrorRateEstimator.adaptive_interval`.
- **`PooledStore`** — a `PagedProtectedStore` subclass whose storage
  primitives address the pool through a per-tenant **block table** instead
  of a private list. Writes to a shared page copy-on-write; `free()` returns
  the pages to the pool; `fork()` clones a store by aliasing its blocks
  (prefix sharing). Encode/scan/decode executables are delegated to the
  pool's template store, so every tenant shares one cached jit per shape.

Allocation failure raises `PoolExhausted` *before* any state is mutated —
the serving engine preflights capacity and evicts, and a caller that races
anyway gets a clean error, never a corrupted block table.
"""
from __future__ import annotations

from collections.abc import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.construction import LDPCCode
from repro.obs import metrics as obs_metrics
from repro.obs import ras as obs_ras

from .controller import ControllerStats
from .paged import PagedProtectedStore

__all__ = ["PoolExhausted", "ProtectedPagePool", "PooledStore"]


class PoolExhausted(RuntimeError):
    """Raised when an allocation needs more pages than the pool has free.

    Raised before any block table or pool state is mutated, so callers can
    evict and retry."""


class ProtectedPagePool:
    """Fixed-capacity pool of (page_words, n) GF pages with a free list,
    ref counts, owner labels, and incremental cold-page scrubbing."""

    def __init__(self, code: str | LDPCCode = "wl1024_r08", *,
                 page_words: int = 256, capacity_pages: int = 64,
                 mesh=None, n_iters: int = 10, damping: float = 0.3,
                 llv_scale: float = 4.0, llv_mode: str = "manhattan",
                 policy=None):
        if capacity_pages <= 0:
            raise ValueError(
                f"capacity_pages must be positive, got {capacity_pages}")
        # the template store carries the code, validation, and the cached
        # encode/scan/decode executables every PooledStore delegates to
        self._template = PagedProtectedStore(
            code, page_words=page_words, mesh=mesh, n_iters=n_iters,
            damping=damping, llv_scale=llv_scale, llv_mode=llv_mode,
            policy=policy)
        self.code = self._template.code
        self.page_words = page_words
        self.mesh = mesh
        self.policy = self._template.policy
        self.capacity_pages = capacity_pages
        self._storage: list[jnp.ndarray | None] = [None] * capacity_pages
        self._refcount = [0] * capacity_pages
        self._owner: list[object | None] = [None] * capacity_pages
        self._stamp = [0] * capacity_pages     # last touch (engine step)
        self._free = list(range(capacity_pages - 1, -1, -1))  # pop() -> 0,1,…
        self._scrub_cursor = 0
        # per-page scrub-flag EWMA + scanned marker: the signal behind
        # prioritized sweeps (hot-flagging pages first) and the RAS
        # estimator's per-owner region feed
        self._flag_ewma = [0.0] * capacity_pages
        self._scanned = [False] * capacity_pages
        self.flag_alpha = 0.3
        self.stats = ControllerStats()         # pool-level scrub aggregates
        self.scrub_by_owner: dict[object, dict] = {}

    # -- introspection ------------------------------------------------------

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return self.capacity_pages - len(self._free)

    def refcount(self, pid: int) -> int:
        return self._refcount[pid]

    def owner(self, pid: int):
        return self._owner[pid]

    # -- allocator ----------------------------------------------------------

    def alloc(self, owner=None) -> int:
        """Take one zeroed page off the free list. Raises `PoolExhausted`
        (mutating nothing) when the pool is full."""
        if not self._free:
            raise PoolExhausted(
                f"pool exhausted: all {self.capacity_pages} pages allocated")
        pid = self._free.pop()
        self._storage[pid] = self._template._new_page()
        self._refcount[pid] = 1
        self._owner[pid] = owner
        self._stamp[pid] = 0
        self._flag_ewma[pid] = 0.0
        self._scanned[pid] = False
        return pid

    def ref(self, pid: int) -> None:
        """Add an aliasing reference (prefix-shared block tables)."""
        if self._refcount[pid] <= 0:
            raise ValueError(f"page {pid} is not allocated")
        self._refcount[pid] += 1

    def free(self, pid: int) -> None:
        """Drop one reference; the page returns to the free list when the
        last reference goes."""
        if self._refcount[pid] <= 0:
            raise ValueError(f"page {pid} is not allocated")
        self._refcount[pid] -= 1
        if self._refcount[pid] == 0:
            self._storage[pid] = None
            self._owner[pid] = None
            self._flag_ewma[pid] = 0.0
            self._scanned[pid] = False
            self._free.append(pid)

    # -- page access --------------------------------------------------------

    def page(self, pid: int) -> jnp.ndarray:
        pg = self._storage[pid]
        if pg is None:
            raise ValueError(f"page {pid} is not allocated")
        return pg

    def set_page(self, pid: int, page: jnp.ndarray) -> None:
        if self._storage[pid] is None:
            raise ValueError(f"page {pid} is not allocated")
        self._storage[pid] = page

    def touch(self, pid: int, step: int) -> None:
        """Record that `pid` was accessed at engine step `step` (drives the
        cold-page selection below and the engine's LRU eviction)."""
        self._stamp[pid] = step

    def stamp(self, pid: int) -> int:
        return self._stamp[pid]

    # -- background scrub ---------------------------------------------------

    def page_flag_rate(self, pid: int) -> float:
        """EWMA fraction of this page's words flagged across scrub scans
        (0.0 until the first scan)."""
        return self._flag_ewma[pid]

    def hot_pages(self, top: int | None = None) -> list[int]:
        """Allocated pages ranked for scrubbing: never-scanned pages first
        (coverage), then by descending flag EWMA (repair pressure)."""
        allocated = [pid for pid in range(self.capacity_pages)
                     if self._storage[pid] is not None]
        ranked = sorted(allocated,
                        key=lambda pid: (self._scanned[pid],
                                         -self._flag_ewma[pid], pid))
        return ranked[:top] if top is not None else ranked

    def scrub(self, *, max_pages: int | None = None, now: int = 0,
              min_age: int = 0, prioritize: bool = False,
              coalesce: bool = True) -> dict:
        """Incrementally sweep allocated pages: scan, repair flagged words,
        write back, attributing repairs to each page's owner.

        A persistent round-robin cursor spreads work across calls;
        `max_pages` caps this call's sweep (the engine interleaves small
        sweeps between decode steps), and `min_age` skips pages touched
        within the last `min_age` steps of `now` — hot pages are about to be
        read (and so corrected) anyway.

        `prioritize=True` replaces the round-robin order with `hot_pages()`:
        never-scanned pages first, then pages by descending scan-flag EWMA,
        so a small `max_pages` budget lands on the pages that have actually
        been flagging (the estimator-driven schedule the serving engine
        uses) instead of whatever the cursor reaches next.

        `coalesce=True` (default) runs the repair pipeline: every in-budget
        page's scan is dispatched before any mask is pulled (one sync per
        sweep), and all tenants' flagged rows coalesce through the shared
        `RepairQueue` into one bucketed drain — the multi-tenant engine's
        background scrub amortizes one drain per step. `coalesce=False`
        keeps the per-page scan→whole-page-decode baseline (bit-identical
        repairs and identical per-owner attribution)."""
        allocated = [pid for pid in range(self.capacity_pages)
                     if self._storage[pid] is not None]
        if not allocated:
            return {"pages": 0, "flagged_words": 0, "repaired_words": 0,
                    "by_owner": {}}
        budget = len(allocated) if max_pages is None else max_pages
        if prioritize:
            order = self.hot_pages()
        else:
            # rotate so the sweep resumes where the previous call stopped
            start = next((j for j, pid in enumerate(allocated)
                          if pid >= self._scrub_cursor), 0)
            order = allocated[start:] + allocated[:start]
        # budget/age selection is identical for both sweep flavors (and
        # independent of scan results), so resolve it up front
        selected: list[int] = []
        for pid in order:
            if len(selected) >= budget:
                break
            if now - self._stamp[pid] < min_age:
                continue
            selected.append(pid)
            if not prioritize:
                self._scrub_cursor = pid + 1
        if self._scrub_cursor >= self.capacity_pages:
            self._scrub_cursor = 0
        if coalesce:
            swept, flagged_words, repaired, by_owner = \
                self._scrub_selected_coalesced(selected)
        else:
            swept, flagged_words, repaired, by_owner = \
                self._scrub_selected_baseline(selected)
        self.stats.scrub_rounds += 1
        self.stats.scrub_words += swept * self.page_words
        self.stats.scrub_corrected += repaired
        self.stats.scrub_uncorrectable += flagged_words - repaired
        reg = obs_metrics.current()
        if reg.enabled:
            reg.counter("pool_scrub_pages", layer="pool").inc(swept)
            reg.counter("pool_scrub_flagged", layer="pool").inc(flagged_words)
            reg.counter("pool_scrub_repaired", layer="pool").inc(repaired)
        for owner, ent in by_owner.items():
            tot = self.scrub_by_owner.setdefault(
                owner, {"flagged_words": 0, "repaired_words": 0})
            tot["flagged_words"] += ent["flagged_words"]
            tot["repaired_words"] += ent["repaired_words"]
            if reg.enabled:
                lab = {"layer": "pool",
                       "tenant": str(owner) if owner is not None else ""}
                reg.counter("pool_scrub_flagged_by_owner", **lab).inc(
                    ent["flagged_words"])
                reg.counter("pool_scrub_repaired_by_owner", **lab).inc(
                    ent["repaired_words"])
        return {"pages": swept, "flagged_words": flagged_words,
                "repaired_words": repaired, "by_owner": by_owner}

    def _note_page_scan(self, pid: int, nf: int, est) -> object:
        """Post-scan bookkeeping shared by both sweep flavors: flag EWMA,
        scanned marker, estimator feed. Returns the page's owner."""
        a = self.flag_alpha if self._scanned[pid] else 1.0
        self._flag_ewma[pid] += a * (nf / self.page_words
                                     - self._flag_ewma[pid])
        self._scanned[pid] = True
        owner = self._owner[pid]
        if est.enabled:
            est.observe_scan(nf, self.page_words, n_symbols=self.code.n,
                             region=str(owner) if owner is not None else "")
        return owner

    def _scrub_selected_baseline(self, selected: list[int]):
        """Per-page sweep over the selected pids: sync each page's flag
        count, decode the whole page when any row flags."""
        scan = self._template._scanner()
        decode = self._template._decoder()
        est = obs_ras.current()
        flagged_words = repaired = 0
        by_owner: dict[object, dict] = {}
        for pid in selected:
            page = self._storage[pid]
            flags = scan(page)
            nf = int(jnp.sum(flags))
            owner = self._note_page_scan(pid, nf, est)
            if not nf:
                continue
            flagged_words += nf
            _y, res = decode(page)
            good = flags & ~res.detect_fail
            self._storage[pid] = jnp.where(good[:, None], res.symbols, page)
            ok = int(jnp.sum(good))
            repaired += ok
            if est.enabled:
                iters = getattr(res, "iterations", None)
                if iters is not None:
                    est.observe_decode(iters, self._template.n_iters,
                                       detect_fail=res.detect_fail,
                                       region=str(owner) if owner is not None
                                       else "")
            ent = by_owner.setdefault(
                owner, {"flagged_words": 0, "repaired_words": 0})
            ent["flagged_words"] += nf
            ent["repaired_words"] += ok
        return len(selected), flagged_words, repaired, by_owner

    def _scrub_selected_coalesced(self, selected: list[int]):
        """Pipelined sweep over the selected pids: every scan dispatched
        before one mask sync, flagged pages pulled whole in a second
        batched sync, flagged rows from every tenant's pages coalesced
        through the shared `RepairQueue`, one bucketed drain (which also
        feeds the estimator per owner region). Row slicing and repair
        writes happen on host page copies: every device op here is
        page-shaped or bucket-shaped, so sweeps reuse warm executables no
        matter how the flag counts vary (a per-flag-count gather/scatter
        would recompile on every new count)."""
        if not selected:
            return 0, 0, 0, {}
        scan = self._template._scanner()
        masks = jax.device_get(
            [scan(self._storage[pid]) for pid in selected])
        est = obs_ras.current()
        queue = self._template._repair_queue()
        flagged_words = 0
        flagged = []
        for pid, mask in zip(selected, masks, strict=True):
            rows = np.flatnonzero(mask)
            owner = self._note_page_scan(pid, int(rows.size), est)
            if rows.size:
                flagged.append((pid, rows, owner))
                flagged_words += int(rows.size)
        pages = jax.device_get([self._storage[pid]
                                for pid, _, _ in flagged])
        for (pid, rows, owner), arr in zip(flagged, pages, strict=True):
            arr = np.array(arr)        # device_get views can be read-only

            def writeback(syms, ok, pid=pid, rows=rows, arr=arr):
                good = rows[ok]
                if good.size:
                    arr[good] = syms[ok].astype(arr.dtype)
                    self._storage[pid] = jnp.asarray(arr, jnp.int32)

            queue.enqueue(arr[rows], writeback, owner=owner,
                          provenance=("pool", pid, rows))
        rep = queue.drain()
        by_owner = {owner: dict(ent)
                    for owner, ent in rep["by_owner"].items()}
        return len(selected), flagged_words, rep["repaired"], by_owner

    # -- fault injection over the whole pool --------------------------------

    def inject(self, channel, key: int | jax.Array, *, t: float = 0.0,
               n_reads: int = 0, owners=None) -> int:
        """Corrupt allocated pool pages in place through a level-domain
        channel (optionally only pages owned by `owners`). Returns cells
        changed. Shared pages are corrupted once — exactly like one physical
        page going bad under every alias."""
        if channel.domain != "level":
            raise ValueError(f"{type(channel).__name__} is an integer-domain "
                             "channel; stored cells need a level-domain one")
        if channel.p != self.code.p:
            raise ValueError(f"channel alphabet {channel.p} != "
                             f"GF({self.code.p})")
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        want = None if owners is None else set(owners)
        changed = 0
        for pid in range(self.capacity_pages):
            page = self._storage[pid]
            if page is None:
                continue
            if want is not None and self._owner[pid] not in want:
                continue
            k = jax.random.fold_in(key, pid)
            new = channel.apply(k, page, t=t, n_reads=n_reads)
            new = new.astype(jnp.int32)
            changed += int(jnp.sum(new != page))
            self._storage[pid] = new
        return changed


class PooledStore(PagedProtectedStore):
    """A `PagedProtectedStore` whose pages live in a shared
    `ProtectedPagePool`, addressed through a per-tenant block table.

    Storage semantics match the standalone store exactly (the whole test
    suite's read/write/inject/scrub behavior carries over); what changes is
    where pages live: appends allocate from the pool, writes to an aliased
    page copy-on-write, and `free()` returns every block. Executables are
    the pool template's — one cached jit per shape for all tenants."""

    def __init__(self, pool: ProtectedPagePool, *, owner=None, key: int = 0):
        super().__init__(pool.code, page_words=pool.page_words,
                         mesh=pool.mesh, n_iters=pool._template.n_iters,
                         damping=pool._template.damping,
                         llv_scale=pool._template.llv_scale,
                         llv_mode=pool._template.llv_mode, key=key,
                         policy=pool.policy)
        self.pool = pool
        self.owner = owner
        self.block_table: list[int] = []
        self._pages = _BlockTableView(self)   # keep `_pages`-style debugging
                                              # (tests poke st._pages[i])

    # -- storage indirection over the pool ----------------------------------

    @property
    def n_pages(self) -> int:
        return len(self.block_table)

    def page(self, i: int) -> jnp.ndarray:
        return self.pool.page(self.block_table[i])

    def _set_page(self, i: int, page: jnp.ndarray) -> None:
        pid = self.block_table[i]
        if self.pool.refcount(pid) > 1:
            # copy-on-write: writing through an aliased block must never be
            # visible to the other tenants holding it
            new_pid = self.pool.alloc(self.owner)
            self.pool.set_page(new_pid, page)
            self.pool._stamp[new_pid] = self.pool._stamp[pid]
            self.pool.free(pid)
            self.block_table[i] = new_pid
        else:
            self.pool.set_page(pid, page)

    def _append_page(self) -> None:
        self.block_table.append(self.pool.alloc(self.owner))

    def _iter_pages(self) -> Iterator[jnp.ndarray]:
        for i in range(self.n_pages):
            yield self.page(i)

    def free(self) -> None:
        for pid in self.block_table:
            self.pool.free(pid)
        self.block_table.clear()
        self._n_words = 0

    def fork(self, owner=None) -> "PooledStore":
        """Clone this store by aliasing every block (prefix sharing): no
        pages are copied until either side writes (copy-on-write)."""
        clone = PooledStore(self.pool, owner=owner)
        for pid in self.block_table:
            self.pool.ref(pid)
            clone.block_table.append(pid)
        clone._n_words = self._n_words
        return clone

    # -- capacity preflight --------------------------------------------------

    def pages_needed(self, m: int) -> int:
        """Worst-case fresh pool pages an `append_words(m rows)` will take:
        new trailing pages plus one CoW copy if the current tail block is
        aliased and partially filled."""
        pw = self.page_words
        slot = self._n_words % pw
        new_pages = -(-(self._n_words + m) // pw) - self.n_pages
        cow = int(slot != 0 and self.block_table
                  and self.pool.refcount(self.block_table[-1]) > 1)
        return max(new_pages, 0) + cow

    def append_words(self, u):
        u = jnp.asarray(u)
        if u.ndim == 2 and u.shape[1] == self.code.k:
            need = self.pages_needed(int(u.shape[0]))
            if need > self.pool.available:
                raise PoolExhausted(
                    f"append of {int(u.shape[0])} words needs {need} pool "
                    f"pages but only {self.pool.available} are free")
        return super().append_words(u)

    def append_encoded(self, enc):
        enc = jnp.asarray(enc, jnp.int32)
        if enc.ndim == 2 and enc.shape[1] == self.code.n:
            need = self.pages_needed(int(enc.shape[0]))
            if need > self.pool.available:
                raise PoolExhausted(
                    f"append of {int(enc.shape[0])} words needs {need} pool "
                    f"pages but only {self.pool.available} are free")
        return super().append_encoded(enc)

    # -- shared executables --------------------------------------------------

    def _encoder(self):
        return self.pool._template._encoder()

    def _scanner(self):
        return self.pool._template._scanner()

    def _decoder(self):
        return self.pool._template._decoder()

    def _repair_queue(self):
        # one shared queue (and one set of bucketed decode executables) for
        # every tenant — cross-tenant repairs coalesce into the same drain
        return self.pool._template._repair_queue()


class _BlockTableView:
    """List-like view of a PooledStore's pages so storage-level debugging
    idioms (`store._pages[i]`, `store._pages[i] = corrupted`) keep working
    against the pool-backed store."""

    def __init__(self, store: PooledStore):
        self._store = store

    def __len__(self) -> int:
        return self._store.n_pages

    def __getitem__(self, i: int) -> jnp.ndarray:
        return self._store.page(i)

    def __setitem__(self, i: int, page) -> None:
        self._store._set_page(i, jnp.asarray(page, jnp.int32))

    def __iter__(self):
        return self._store._iter_pages()

    def __bool__(self) -> bool:
        return self._store.n_pages > 0

    def clear(self) -> None:  # PagedProtectedStore.free() compatibility
        self._store.free()
