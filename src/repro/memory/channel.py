"""Composable MLC memristor channel models (memory mode, paper §3.1/§3.3).

The PIM-mode fault model perturbs MAC *outputs*; in memory mode the stored
cells themselves degrade. Multi-level cells fail in structured, asymmetric
ways the uniform symbol-flip model cannot express:

- **level-transition errors** — adjacent-level confusion with different
  up/down probabilities (programming variance, conductance overlap);
- **retention drift** — conductance relaxes toward a rest level over time,
  so the error rate grows with storage age `t`;
- **read disturb** — every read nudges cells toward higher conductance, so
  the error rate grows with the read count `n_reads`;
- **stuck-at cells** — a static population of dead cells pinned to one level.

Every model is a frozen dataclass with an `apply(key, levels, *, t, n_reads)`
method driven by an explicit `jax.random` key: same key, same faults —
corruption is reproducible and shardable. Levels live in `[0, p)` (field
symbols / cell levels). `PlusMinusOne` is the one *integer-domain* channel
(PIM MAC outputs, unbounded integers); `ProtectedMemoryArray` only accepts
level-domain channels.

Matrix-backed channels expose their per-cell level-transition matrix via
`transition(t, n_reads)` — a (p, p) row-stochastic matrix validated at
construction — which the semi-analytic BER campaign uses to draw
conditional error values (`corrupt_exact`).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Channel", "LevelTransition", "RetentionDrift", "ReadDisturb",
    "StuckAt", "Compose", "PlusMinusOne", "uniform_flip",
    "asymmetric_adjacent", "validate_transition",
]


def validate_transition(T: np.ndarray, atol: float = 1e-6) -> np.ndarray:
    """Validate a level-transition matrix: square, non-negative entries,
    rows summing to 1 (row-stochastic). Returns the matrix as float64."""
    T = np.asarray(T, np.float64)
    if T.ndim != 2 or T.shape[0] != T.shape[1]:
        raise ValueError(f"transition matrix must be square, got {T.shape}")
    if (T < -atol).any():
        raise ValueError("transition matrix has negative entries")
    rows = T.sum(axis=1)
    if not np.allclose(rows, 1.0, atol=atol):
        raise ValueError(f"transition matrix rows must sum to 1, got {rows}")
    return np.clip(T, 0.0, None)


def _sample_rows(key: jax.Array, T: np.ndarray, levels: jnp.ndarray):
    """Sample next levels: one draw per cell from T[levels[...]]."""
    cdf = jnp.asarray(np.cumsum(T, axis=1))
    u = jax.random.uniform(key, levels.shape, jnp.float32)
    # count of cdf entries strictly below u == sampled index; the clamp
    # guards the validate_transition tolerance (row sum 1 - atol in float32
    # could otherwise emit the out-of-alphabet level p)
    idx = (u[..., None] > cdf[levels]).sum(axis=-1)
    return jnp.minimum(idx, T.shape[0] - 1).astype(levels.dtype)


class Channel:
    """Base class: a stochastic map on stored cell levels."""

    domain = "level"            # "level" (cells in [0,p)) | "integer"

    @property
    def p(self) -> int:
        raise NotImplementedError

    def apply(self, key: jax.Array, levels: jnp.ndarray, *, t: float = 0.0,
              n_reads: int = 0) -> jnp.ndarray:
        """Corrupt `levels` (any shape). Deterministic given `key`."""
        raise NotImplementedError

    def transition(self, t: float = 0.0, n_reads: int = 0) -> np.ndarray:
        """(p, p) row-stochastic per-cell transition matrix, when the model
        is i.i.d. per cell. Channels without one raise TypeError."""
        raise TypeError(f"{type(self).__name__} has no per-cell transition "
                        "matrix (stateful/correlated channel)")

    def error_rate(self, *, t: float = 0.0, n_reads: int = 0) -> float:
        """Marginal per-cell error probability under a uniform level prior."""
        T = self.transition(t, n_reads)
        return float(1.0 - np.diag(T).mean())

    def corrupt_exact(self, key: jax.Array, words: jnp.ndarray, m: int, *,
                      t: float = 0.0, n_reads: int = 0) -> jnp.ndarray:
        """Corrupt exactly `m` distinct cells per word (rows of `words`),
        drawing wrong values from this channel's conditional-on-error
        distribution. This is the sampler behind the semi-analytic BER
        campaign (post_BER = sum_m Binom(n, eps, m) * r(m))."""
        T = self.transition(t, n_reads)
        p = T.shape[0]
        E = T.copy()
        np.fill_diagonal(E, 0.0)
        rowsum = E.sum(axis=1, keepdims=True)
        # rows with no off-diagonal mass (e.g. absorbing level) stay put
        safe = rowsum > 0
        E = np.where(safe, E / np.where(safe, rowsum, 1.0), np.eye(p))
        B, n = words.shape
        kpos, kval = jax.random.split(key)
        perm = jax.vmap(lambda k: jax.random.permutation(k, n))(
            jax.random.split(kpos, B))
        pos = perm[:, :m]                                        # (B, m)
        cur = jnp.take_along_axis(words, pos, axis=1)
        new = _sample_rows(kval, E, cur)
        return words.at[jnp.arange(B)[:, None], pos].set(new)


@dataclasses.dataclass(frozen=True)
class LevelTransition(Channel):
    """General i.i.d. per-cell channel defined by a (p, p) row-stochastic
    level-transition matrix T: P(read level j | stored level i) = T[i, j]."""

    T: np.ndarray

    def __post_init__(self):
        object.__setattr__(self, "T", validate_transition(self.T))

    @property
    def p(self) -> int:
        return self.T.shape[0]

    def transition(self, t: float = 0.0, n_reads: int = 0) -> np.ndarray:
        return self.T

    def apply(self, key, levels, *, t=0.0, n_reads=0):
        return _sample_rows(key, self.T, levels)


def uniform_flip(p: int, eps: float) -> LevelTransition:
    """Uniform symbol-flip: with prob eps, replace with a uniformly random
    *other* level (the model the seed repo used implicitly)."""
    T = np.full((p, p), eps / (p - 1))
    np.fill_diagonal(T, 1.0 - eps)
    return LevelTransition(T)


def asymmetric_adjacent(p: int, eps_up: float, eps_down: float
                        ) -> LevelTransition:
    """Adjacent-level confusion with asymmetric up/down rates — the dominant
    MLC memristor read-error mode (conductance-distribution overlap is wider
    toward the high-resistance state). Boundary levels only err inward."""
    T = np.eye(p)
    for i in range(p):
        up = eps_up if i + 1 < p else 0.0
        down = eps_down if i > 0 else 0.0
        T[i, i] = 1.0 - up - down
        if i + 1 < p:
            T[i, i + 1] = up
        if i > 0:
            T[i, i - 1] = down
    return LevelTransition(T)


@dataclasses.dataclass(frozen=True)
class RetentionDrift(Channel):
    """Conductance relaxation over storage time: each cell independently
    drifts one level toward `rest_level` with probability 1 - exp(-rate * t).
    Cells already at the rest level are stable (absorbing)."""

    p_levels: int
    rate: float
    rest_level: int = 0

    @property
    def p(self) -> int:
        return self.p_levels

    def transition(self, t: float = 0.0, n_reads: int = 0) -> np.ndarray:
        q = 1.0 - math.exp(-self.rate * max(t, 0.0))
        T = np.eye(self.p_levels)
        for i in range(self.p_levels):
            step = int(np.sign(self.rest_level - i))
            if step:
                T[i, i] = 1.0 - q
                T[i, i + step] = q
        return T

    def apply(self, key, levels, *, t=0.0, n_reads=0):
        return _sample_rows(key, self.transition(t), levels)


@dataclasses.dataclass(frozen=True)
class ReadDisturb(Channel):
    """Read-disturb accumulation: every read nudges a cell one level toward
    `disturb_level` (the programmed/high-conductance end) with per-read
    probability `per_read`; after n reads the cumulative disturb probability
    is 1 - (1 - per_read)^n."""

    p_levels: int
    per_read: float
    disturb_level: int | None = None      # default: top level p-1

    @property
    def p(self) -> int:
        return self.p_levels

    def transition(self, t: float = 0.0, n_reads: int = 0) -> np.ndarray:
        target = (self.p_levels - 1 if self.disturb_level is None
                  else self.disturb_level)
        q = 1.0 - (1.0 - self.per_read) ** max(n_reads, 0)
        T = np.eye(self.p_levels)
        for i in range(self.p_levels):
            step = int(np.sign(target - i))
            if step:
                T[i, i] = 1.0 - q
                T[i, i + step] = q
        return T

    def apply(self, key, levels, *, t=0.0, n_reads=0):
        return _sample_rows(key, self.transition(n_reads=n_reads), levels)


@dataclasses.dataclass(frozen=True)
class StuckAt(Channel):
    """A static population of dead cells pinned at `stuck_level`. The stuck
    mask is a function of (seed, array shape) only — the *same* cells are
    stuck on every apply, across reads and scrubs, as in real arrays."""

    p_levels: int
    fraction: float
    stuck_level: int = 0
    seed: int = 0

    @property
    def p(self) -> int:
        return self.p_levels

    def mask(self, shape: tuple[int, ...]) -> jnp.ndarray:
        return jax.random.bernoulli(jax.random.PRNGKey(self.seed),
                                    self.fraction, shape)

    def error_rate(self, *, t: float = 0.0, n_reads: int = 0) -> float:
        # a stuck cell is only *wrong* when the stored level differs
        return self.fraction * (self.p_levels - 1) / self.p_levels

    def apply(self, key, levels, *, t=0.0, n_reads=0):
        del key  # stuck cells are deterministic in (seed, shape)
        return jnp.where(self.mask(levels.shape),
                         jnp.asarray(self.stuck_level, levels.dtype), levels)


@dataclasses.dataclass(frozen=True)
class Compose(Channel):
    """Sequential composition: physics stack (e.g. drift, then read disturb,
    then stuck cells). Sub-keys are folded per stage, so the composite is as
    deterministic as its parts."""

    channels: tuple[Channel, ...]

    def __init__(self, *channels: Channel):
        if not channels:
            raise ValueError("Compose needs at least one channel")
        ps = {c.p for c in channels}
        if len(ps) != 1:
            raise ValueError(f"mixed alphabet sizes in Compose: {ps}")
        object.__setattr__(self, "channels", tuple(channels))

    @property
    def p(self) -> int:
        return self.channels[0].p

    def transition(self, t: float = 0.0, n_reads: int = 0) -> np.ndarray:
        # defined when every stage is i.i.d. per cell: matrix product
        T = np.eye(self.p)
        for c in self.channels:
            T = T @ c.transition(t, n_reads)
        return validate_transition(T)

    def apply(self, key, levels, *, t=0.0, n_reads=0):
        for i, c in enumerate(self.channels):
            levels = c.apply(jax.random.fold_in(key, i), levels,
                             t=t, n_reads=n_reads)
        return levels


@dataclasses.dataclass(frozen=True)
class PlusMinusOne(Channel):
    """The paper's ±1 *integer-error* channel (PIM-mode MAC outputs and the
    BER-campaign reference channel): each integer is hit with probability
    `eps`; a hit adds +1 with probability `up` else -1. Operates on
    unbounded integers, not cell levels."""

    eps: float
    up: float = 0.5
    p_field: int = 3              # field the protecting code works over

    domain = "integer"

    @property
    def p(self) -> int:
        return self.p_field

    def error_rate(self, *, t: float = 0.0, n_reads: int = 0) -> float:
        return self.eps

    def apply(self, key, y, *, t=0.0, n_reads=0):
        khit, ksign = jax.random.split(key)
        hit = jax.random.bernoulli(khit, self.eps, y.shape)
        sign = jnp.where(jax.random.bernoulli(ksign, self.up, y.shape), 1, -1)
        return y + jnp.where(hit, sign, 0).astype(y.dtype)

    def corrupt_exact(self, key, words, m, *, t=0.0, n_reads=0):
        B, n = words.shape
        kpos, ksign = jax.random.split(key)
        perm = jax.vmap(lambda k: jax.random.permutation(k, n))(
            jax.random.split(kpos, B))
        pos = perm[:, :m]
        sign = jnp.where(jax.random.bernoulli(ksign, self.up, (B, m)), 1, -1)
        cur = jnp.take_along_axis(words, pos, axis=1)
        return words.at[jnp.arange(B)[:, None], pos].set(
            cur + sign.astype(words.dtype))
