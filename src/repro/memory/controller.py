"""Pluggable memory-controller policies for NB-LDPC-protected storage.

Modeled on the classic ECC-memory-controller taxonomy (basic / write-back /
refresh):

- **basic** — correct read responses, never touch the stored words; latent
  errors accumulate in storage until they exceed the code's strength.
- **writeback** — additionally rewrite every corrected word back into
  storage on read, so each read also repairs (read-triggered refresh).
- **scrub** — writeback plus a periodic background sweep over the whole
  array: syndromes are scanned for every stored word, flagged words are
  batch-decoded (sharded across local devices via
  `repro.distributed.sharding.decode_sharded` when more than one is
  visible) and repaired in place. `interval` counts read/write operations
  between automatic sweeps; `scrub()` can also be called explicitly.

All policies share the same read path: a cheap host-side syndrome scan over
the stored words, then the iterative decoder runs ONLY on flagged words,
gathered into fixed-size chunks so one jitted executable serves every read
(the same trick as `repro.core.protected.decode_stream`). Per-policy
counters (detected / corrected / uncorrectable / writebacks / scrub
bandwidth) live in `ControllerStats`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.construction import LDPCCode
from repro.core.decode import decode_integers

__all__ = ["ControllerStats", "MemoryController", "WritebackController",
           "ScrubController", "make_controller"]


@dataclasses.dataclass
class ControllerStats:
    reads: int = 0
    writes: int = 0
    words_read: int = 0
    words_written: int = 0
    detected: int = 0              # words with nonzero syndrome seen on read
    corrected: int = 0             # flagged words the decoder fixed
    uncorrectable: int = 0         # flagged words with residual syndrome
    writebacks: int = 0            # corrected words rewritten into storage
    scrub_rounds: int = 0
    scrub_words: int = 0           # words syndrome-scanned by scrubbing
    scrub_cells: int = 0           # cells scanned (words * n)
    scrub_corrected: int = 0
    scrub_uncorrectable: int = 0
    scrub_seconds: float = 0.0

    @property
    def scrub_bandwidth_cells_per_s(self) -> float:
        return self.scrub_cells / self.scrub_seconds if self.scrub_seconds \
            else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["scrub_bandwidth_cells_per_s"] = self.scrub_bandwidth_cells_per_s
        return d


class MemoryController:
    """`basic` policy: correct-on-read, storage untouched."""

    policy = "basic"

    def __init__(self, *, n_iters: int = 10, damping: float = 0.3,
                 llv_scale: float = 4.0, llv_mode: str = "manhattan",
                 chunk_size: int = 256, use_sharded: Optional[bool] = None):
        self.n_iters = n_iters
        self.damping = damping
        self.llv_scale = llv_scale
        self.llv_mode = llv_mode
        self.chunk_size = chunk_size
        self.use_sharded = (len(jax.devices()) > 1 if use_sharded is None
                            else use_sharded)
        self.stats = ControllerStats()
        self._jit_cache: Dict[int, Tuple[LDPCCode, object]] = {}

    # -- decode plumbing ----------------------------------------------------

    def _decoder(self, code: LDPCCode):
        """One jitted fixed-shape (chunk_size, n) decoder per code."""
        hit = self._jit_cache.get(id(code))
        if hit is not None and hit[0] is code:
            return hit[1]

        if self.use_sharded:
            from repro.distributed.sharding import data_mesh, decode_sharded
            mesh = data_mesh()

            def run(y):
                return decode_sharded(code, y, mesh=mesh,
                                      n_iters=self.n_iters,
                                      llv_scale=self.llv_scale,
                                      llv_mode=self.llv_mode,
                                      damping=self.damping, early_exit=True)
        else:
            def run(y):
                return decode_integers(code, y, n_iters=self.n_iters,
                                       llv_scale=self.llv_scale,
                                       llv_mode=self.llv_mode,
                                       damping=self.damping, early_exit=True)

        fn = jax.jit(run)
        self._jit_cache[id(code)] = (code, fn)
        return fn

    def _decode_words(self, code: LDPCCode, words: np.ndarray):
        """Decode (B, n) stored level-words -> (symbols (B, n), fail (B,)).
        Chunks are padded to `chunk_size` so one executable serves any B."""
        fn = self._decoder(code)
        B = words.shape[0]
        cs = self.chunk_size
        syms = np.empty((B, code.n), np.int64)
        fail = np.empty(B, bool)
        for lo in range(0, B, cs):
            chunk = words[lo:lo + cs].astype(np.int32)
            b = chunk.shape[0]
            if b < cs:
                chunk = np.concatenate(
                    [chunk, np.zeros((cs - b, code.n), np.int32)])
            _y, res = fn(jnp.asarray(chunk))
            syms[lo:lo + b] = np.asarray(res.symbols[:b])
            fail[lo:lo + b] = np.asarray(res.detect_fail[:b])
        return syms, fail

    @staticmethod
    def _scan_syndromes(code: LDPCCode, enc: np.ndarray) -> np.ndarray:
        """Host-side syndrome scan -> flagged mask (B,). This is the cheap
        always-on part of the read path; decode runs only on flags.

        Runs in float32 so the matmul hits BLAS (NumPy integer matmul is a
        slow C loop — this is the scrub-bandwidth hot path). Exact because
        every accumulated product is bounded by n*(p-1)^2 << 2^24."""
        assert code.n * (code.p - 1) ** 2 < 2 ** 24
        s = enc.astype(np.float32) @ code.H.T.astype(np.float32)
        return np.any(s.astype(np.int64) % code.p != 0, axis=1)

    def _correct(self, code: LDPCCode, enc: np.ndarray):
        """-> (corrected levels (B, n), flagged, fail) without stats."""
        flagged = self._scan_syndromes(code, enc)
        out = enc.astype(np.int64) % code.p
        fail = np.zeros(enc.shape[0], bool)
        if flagged.any():
            syms, f = self._decode_words(code, enc[flagged])
            out[flagged] = syms
            fail[flagged] = f
        return out, flagged, fail

    # -- policy surface -----------------------------------------------------

    def read(self, code: LDPCCode, store: dict, name: str) -> np.ndarray:
        st = store[name]
        out, flagged, fail = self._correct(code, st.enc)
        self.stats.reads += 1
        self.stats.words_read += st.enc.shape[0]
        self.stats.detected += int(flagged.sum())
        self.stats.corrected += int((flagged & ~fail).sum())
        self.stats.uncorrectable += int(fail.sum())
        self._writeback(st, out, flagged, fail)
        return out

    def _writeback(self, st, corrected: np.ndarray, flagged: np.ndarray,
                   fail: np.ndarray) -> None:
        pass                        # basic: never touch storage

    def note_write(self, n_words: int) -> None:
        self.stats.writes += 1
        self.stats.words_written += n_words

    def tick(self, code: LDPCCode, store: dict) -> None:
        pass                        # only the scrub policy acts on ticks

    def scrub(self, code: LDPCCode, store: dict) -> dict:
        """Full-array sweep: scan every stored word, repair flagged words in
        place (every policy may be scrubbed explicitly; only
        `ScrubController` does it automatically). Returns a report with the
        sweep's counts and scan bandwidth."""
        t0 = time.perf_counter()
        words = flagged_n = corrected_n = fail_n = 0
        for st in store.values():
            out, flagged, fail = self._correct(code, st.enc)
            ok = flagged & ~fail
            if ok.any():
                st.enc[ok] = out[ok].astype(st.enc.dtype)
            words += st.enc.shape[0]
            flagged_n += int(flagged.sum())
            corrected_n += int(ok.sum())
            fail_n += int(fail.sum())
        dt = time.perf_counter() - t0
        self.stats.scrub_rounds += 1
        self.stats.scrub_words += words
        self.stats.scrub_cells += words * code.n
        self.stats.scrub_corrected += corrected_n
        self.stats.scrub_uncorrectable += fail_n
        self.stats.scrub_seconds += dt
        return {"policy": self.policy, "words_scanned": words,
                "cells_scanned": words * code.n, "flagged": flagged_n,
                "corrected": corrected_n, "uncorrectable": fail_n,
                "seconds": dt,
                "bandwidth_cells_per_s": words * code.n / dt if dt else 0.0}


class WritebackController(MemoryController):
    """`writeback` policy: reads repair storage as a side effect."""

    policy = "writeback"

    def _writeback(self, st, corrected, flagged, fail):
        ok = flagged & ~fail
        if ok.any():
            st.enc[ok] = corrected[ok].astype(st.enc.dtype)
            self.stats.writebacks += int(ok.sum())


class ScrubController(WritebackController):
    """`scrub` policy: writeback + a background sweep every `interval`
    read/write operations."""

    policy = "scrub"

    def __init__(self, *, interval: int = 16, **kw):
        super().__init__(**kw)
        self.interval = interval
        self._ops = 0

    def tick(self, code: LDPCCode, store: dict) -> None:
        self._ops += 1
        if self._ops % self.interval == 0:
            self.scrub(code, store)


_POLICIES = {"basic": MemoryController, "writeback": WritebackController,
             "scrub": ScrubController}


def make_controller(spec, **kw) -> MemoryController:
    """spec: a policy name ("basic" | "writeback" | "scrub"), a controller
    instance (passed through), or None (basic)."""
    if isinstance(spec, MemoryController):
        return spec
    if spec is None:
        spec = "basic"
    if spec not in _POLICIES:
        raise KeyError(f"unknown controller policy {spec!r}; "
                       f"available: {sorted(_POLICIES)}")
    return _POLICIES[spec](**kw)
