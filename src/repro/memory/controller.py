"""Pluggable memory-controller policies for NB-LDPC-protected storage.

Modeled on the classic ECC-memory-controller taxonomy (basic / write-back /
refresh):

- **basic** — correct read responses, never touch the stored words; latent
  errors accumulate in storage until they exceed the code's strength.
- **writeback** — additionally rewrite every corrected word back into
  storage on read, so each read also repairs (read-triggered refresh).
- **scrub** — writeback plus a periodic background sweep over the whole
  array: syndromes are scanned for every stored word, flagged words are
  batch-decoded (sharded across local devices via
  `repro.distributed.sharding.decode_sharded` when more than one is
  visible) and repaired in place. `interval` counts read/write operations
  between automatic sweeps; `scrub()` can also be called explicitly.

All policies share the same read path: a cheap syndrome scan over the
stored words, then the iterative decoder runs ONLY on flagged words,
gathered into fixed-size chunks so one jitted executable serves every read
(the same trick as `repro.core.protected.decode_stream`). Per-policy
counters (detected / corrected / uncorrectable / writebacks / scrub
bandwidth) live in `ControllerStats`.

The scan itself has two routes, selected by the controller's pinned
`KernelPolicy` (`policy=`) or the ambient `repro.kernels.use_policy` —
`ref` mode runs the host scan, every other mode the device scan:

- **host** — float32 BLAS matmul (exact while n·(p−1)² < 2²⁴; beyond that
  it degrades to an exact-but-slower int64 path automatically);
- **device** — the fused Pallas `repro.kernels.ops.scan_syndromes` kernel:
  mod-p + any-reduce fused into the matmul epilogue, so only the (B,) flag
  mask crosses back to the host, never the syndrome matrix. Pages are
  streamed through ONE cached fixed-shape executable (`scan_block` rows)
  and fanned across local devices via the `decode_sharded` mesh when more
  than one is visible. The default `auto` mode compiles on TPU and
  interprets elsewhere (a correctness path, not a fast path, on CPU).

Every read and sweep also feeds the ambient observability layer
(`repro.obs`): correction counters into the metrics registry, per-page
scan-flag rates and decoder iteration vectors into the RAS estimator —
both free no-ops unless `use_metrics` / `use_estimator` is active.

Scrubbing is **paged**: `scrub(page_words=...)` streams fixed-size pages of
stored words (`scrub_pages` accepts any iterator of writable (b, n) row
views) so arrays larger than device memory scrub incrementally; repairs are
written back through the page views and per-page stats ride in the report.
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.construction import LDPCCode
from repro.core.decode import decode_integers
from repro.obs import metrics as obs_metrics
from repro.obs import ras as obs_ras

from .repair import RepairQueue

__all__ = ["ControllerStats", "MemoryController", "WritebackController",
           "ScrubController", "make_controller"]

# per-page entries kept in a sweep report; totals keep accumulating past
# this, so a million-page archive sweep stays one-page-resident instead of
# holding millions of stat dicts
MAX_PAGE_STATS = 1024


@dataclasses.dataclass
class ControllerStats:
    reads: int = 0
    writes: int = 0
    words_read: int = 0
    words_written: int = 0
    detected: int = 0              # words with nonzero syndrome seen on read
    corrected: int = 0             # flagged words the decoder fixed
    uncorrectable: int = 0         # flagged words with residual syndrome
    writebacks: int = 0            # corrected words rewritten into storage
    scrub_rounds: int = 0
    scrub_words: int = 0           # words syndrome-scanned by scrubbing
    scrub_cells: int = 0           # cells scanned (words * n)
    scrub_corrected: int = 0
    scrub_uncorrectable: int = 0
    scrub_seconds: float = 0.0

    CORRECTION_KEYS = ("detected", "corrected", "uncorrectable")

    @property
    def scrub_bandwidth_cells_per_s(self) -> float:
        return self.scrub_cells / self.scrub_seconds if self.scrub_seconds \
            else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["scrub_bandwidth_cells_per_s"] = self.scrub_bandwidth_cells_per_s
        return d

    def merge(self, other: "ControllerStats") -> "ControllerStats":
        """Accumulate another stats block into this one (all counters sum;
        returns self so merges chain)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self

    def correction_counts(self) -> dict[str, int]:
        """The read-path correction triple every per-tenant report uses."""
        return {k: getattr(self, k) for k in self.CORRECTION_KEYS}

    @staticmethod
    def add_counts(out: dict[str, int], src) -> dict[str, int]:
        """Add one correction-count source (a `ControllerStats` or any dict
        holding the triple) into `out` in place. The single merge helper
        behind every detected/corrected/uncorrectable summation in the
        serving layer — see `ServingEngine.tenant_stats`."""
        get = src.correction_counts().get if isinstance(
            src, ControllerStats) else src.get
        for k in ControllerStats.CORRECTION_KEYS:
            out[k] = out.get(k, 0) + int(get(k, 0))
        return out

    def publish(self, registry, **labels) -> None:
        """Export every counter into a `MetricsRegistry` as gauges (the
        stats are already cumulative totals, so gauge-set is idempotent
        across repeated publishes — counter-inc would double count)."""
        if registry is None or not getattr(registry, "enabled", False):
            return
        for f in dataclasses.fields(self):
            registry.gauge(f"controller_{f.name}", **labels).set(
                getattr(self, f.name))


class MemoryController:
    """`basic` policy: correct-on-read, storage untouched."""

    policy = "basic"

    def __init__(self, *, n_iters: int = 10, damping: float = 0.3,
                 llv_scale: float = 4.0, llv_mode: str = "manhattan",
                 chunk_size: int = 256, use_sharded: bool | None = None,
                 scan_block: int = 512,
                 page_words: int | None = None, policy=None):
        # `policy=` pins a KernelPolicy for this controller's scans; the
        # class-level `policy` name ("basic"/"writeback"/"scrub") stays the
        # policy *name*, so scrub reports label themselves correctly
        if policy is not None:
            from repro.kernels.backend import _as_policy
            policy = _as_policy(policy)
        self.kernel_policy = policy
        self.n_iters = n_iters
        self.damping = damping
        self.llv_scale = llv_scale
        self.llv_mode = llv_mode
        self.chunk_size = chunk_size
        self.use_sharded = (len(jax.devices()) > 1 if use_sharded is None
                            else use_sharded)
        self.scan_block = scan_block
        self.page_words = page_words          # default paging for sweeps
        self.stats = ControllerStats()
        self._jit_cache: dict[int, tuple[LDPCCode, object]] = {}
        self._scan_cache: dict[int, tuple[LDPCCode, object]] = {}
        self._host_ht_cache: dict[int, tuple[LDPCCode, np.ndarray]] = {}
        self._repair_cache: dict[int, tuple[LDPCCode, RepairQueue]] = {}

    # -- decode plumbing ----------------------------------------------------

    def _decoder(self, code: LDPCCode):
        """One jitted fixed-shape (chunk_size, n) decoder per code."""
        hit = self._jit_cache.get(id(code))
        if hit is not None and hit[0] is code:
            return hit[1]

        if self.use_sharded:
            from repro.distributed.sharding import data_mesh, decode_sharded
            mesh = data_mesh()

            def run(y):
                return decode_sharded(code, y, mesh=mesh,
                                      n_iters=self.n_iters,
                                      llv_scale=self.llv_scale,
                                      llv_mode=self.llv_mode,
                                      damping=self.damping, early_exit=True)
        else:
            def run(y):
                return decode_integers(code, y, n_iters=self.n_iters,
                                       llv_scale=self.llv_scale,
                                       llv_mode=self.llv_mode,
                                       damping=self.damping, early_exit=True)

        fn = jax.jit(run)
        self._jit_cache[id(code)] = (code, fn)
        return fn

    @staticmethod
    def _pad_block(chunk: np.ndarray, size: int, n: int):
        """Zero-pad a ragged tail block to the executable's fixed row count
        (zero words are valid codewords: unflagged, converge immediately).
        Returns (padded int32 block, true row count)."""
        chunk = chunk.astype(np.int32)
        b = chunk.shape[0]
        if b < size:
            chunk = np.concatenate([chunk, np.zeros((size - b, n), np.int32)])
        return chunk, b

    def _repair_queue(self, code: LDPCCode) -> RepairQueue:
        """One coalescing repair queue per code (it owns the bucketed
        decode executables every repair on this controller routes through)."""
        hit = self._repair_cache.get(id(code))
        if hit is not None and hit[0] is code:
            return hit[1]
        q = RepairQueue(code, chunk_size=self.chunk_size,
                        n_iters=self.n_iters, damping=self.damping,
                        llv_scale=self.llv_scale, llv_mode=self.llv_mode,
                        use_sharded=self.use_sharded)
        self._repair_cache[id(code)] = (code, q)
        return q

    def _decode_words(self, code: LDPCCode, words: np.ndarray):
        """Decode (B, n) stored level-words -> (symbols (B, n), fail (B,))
        through the repair queue's bucketed executables (8/16/…/chunk_size
        rows): sparse reads no longer pad to a full chunk, every chunk
        dispatches asynchronously, and one host sync resolves the batch."""
        syms, fail, iters, _pad = self._repair_queue(code).decode_batch(words)
        est = obs_ras.current()
        if est.enabled and iters is not None:
            # outputs are concrete here (post-sync) — feed decoder-stress/
            # fail telemetry to the RAS estimator
            est.observe_decode(iters, self.n_iters, detect_fail=fail)
        return syms, fail

    def _decode_words_legacy(self, code: LDPCCode, words: np.ndarray):
        """The pre-coalescing decode path: every chunk pads to the full
        `chunk_size` executable and syncs to host before the next dispatch.
        Kept as the measured baseline behind `scrub_pages(coalesce=False)`
        (the repair-parity tests and `bench_scrub`'s repair-throughput
        section diff the coalesced pipeline against it)."""
        fn = self._decoder(code)
        est = obs_ras.current()
        B = words.shape[0]
        cs = self.chunk_size
        syms = np.empty((B, code.n), np.int64)
        fail = np.empty(B, bool)
        for lo in range(0, B, cs):
            chunk, b = self._pad_block(words[lo:lo + cs], cs, code.n)
            _y, res = fn(jnp.asarray(chunk))
            syms[lo:lo + b] = np.asarray(res.symbols[:b])  # noqa: RPL007 - per-chunk sync IS the measured baseline
            fail[lo:lo + b] = np.asarray(res.detect_fail[:b])  # noqa: RPL007 - per-chunk sync IS the measured baseline
            if est.enabled:
                iters = getattr(res, "iterations", None)
                if iters is not None:
                    est.observe_decode(np.asarray(iters)[:b], self.n_iters,  # noqa: RPL007 - concrete post-sync values
                                       detect_fail=fail[lo:lo + b])
        return syms, fail

    # -- syndrome-scan backends ---------------------------------------------

    def _scan_mode(self) -> str:
        """Resolved kernel mode for scans: the controller's pinned policy,
        else the ambient one."""
        from repro.kernels.backend import current_policy
        return (self.kernel_policy or current_policy()).resolve()

    def resolved_scan_backend(self) -> str:
        # "ref" mode is the host BLAS/int64 scan; compiled and interpret
        # both run the device (Pallas) scan executable. Matches the legacy
        # scan_backend mapping: auto -> device only on TPU, host -> ref,
        # device -> Pallas (interpreted off-TPU).
        return "host" if self._scan_mode() == "ref" else "device"

    def _scan_route(self, code: LDPCCode) -> str:
        """The backend a scan of `code` ACTUALLY runs on: the device kernel
        accumulates in int32, so fields/words beyond its exact bound route
        to the host scan (whose own fallback is int64) even when the device
        backend is configured."""
        if (self.resolved_scan_backend() == "device"
                and code.n * (code.p - 1) ** 2 < 2 ** 31):
            return "device"
        return "host"

    def _scan_syndromes(self, code: LDPCCode, enc: np.ndarray) -> np.ndarray:
        """Syndrome scan -> flagged mask (B,). This is the cheap always-on
        part of the read path; decode runs only on flags."""
        if self._scan_route(code) == "device":
            return self._scan_syndromes_device(code, enc)
        return self._scan_syndromes_host(code, enc)

    def _host_ht(self, code: LDPCCode, dtype) -> np.ndarray:
        """Per-code cache of the transposed+cast check matrix: paged sweeps
        call the host scan once per page, and the (c, n) conversion must not
        be repaid on every page (mirrors `_scanner`'s cached executable)."""
        hit = self._host_ht_cache.get(id(code))
        if hit is not None and hit[0] is code and hit[1].dtype == dtype:
            return hit[1]
        ht = code.H.T.astype(dtype)
        self._host_ht_cache[id(code)] = (code, ht)
        return ht

    def _scan_syndromes_host(self, code: LDPCCode,
                             enc: np.ndarray) -> np.ndarray:
        """float32 BLAS scan (NumPy integer matmul is a slow C loop — this
        is the host scrub-bandwidth hot path), exact while every accumulated
        product is bounded by n*(p-1)^2 < 2^24; large-field / long-word
        codes beyond that fall back to the exact int64 path."""
        if code.n * (code.p - 1) ** 2 < 2 ** 24:
            s = (enc.astype(np.float32)
                 @ self._host_ht(code, np.float32)).astype(np.int64)
        else:
            s = enc.astype(np.int64) @ self._host_ht(code, np.int64)
        return np.any(s % code.p != 0, axis=1)

    def _scanner(self, code: LDPCCode):
        """One jitted fixed-shape (scan_block, n) fused scan per code,
        shard_map'd over the local device mesh when more than one device is
        visible (same dispatch shape as `_decoder`)."""
        hit = self._scan_cache.get(id(code))
        if hit is not None and hit[0] is code:
            return hit[1]

        if self.use_sharded:
            from repro.distributed.sharding import (data_mesh,
                                                    scan_syndromes_sharded)
            mesh = data_mesh()

            def run(y):
                return scan_syndromes_sharded(code, y, mesh=mesh)
        else:
            from repro.kernels.ops import scan_syndromes
            ht = jnp.asarray(code.H.T, jnp.int32)
            # bake the resolved interpret flag in at build time so a later
            # ambient-policy change can't retarget this cached executable
            interp = self._scan_mode() != "compiled"

            def run(y):
                return scan_syndromes(y, ht, code.p, interpret=interp)

        fn = jax.jit(run)
        self._scan_cache[id(code)] = (code, fn)
        return fn

    def _scan_syndromes_device(self, code: LDPCCode,
                               enc: np.ndarray) -> np.ndarray:
        """Fused Pallas scan: pages are streamed through one cached
        executable in fixed `scan_block`-row slices (zero-padded tails are
        valid codewords — never flagged); only the (b,) mask comes back.
        Every block scan is dispatched before any mask is pulled, so the
        device pipelines the whole page and the host syncs exactly once."""
        fn = self._scanner(code)
        B = enc.shape[0]
        sb = self.scan_block
        launched = []
        for lo in range(0, B, sb):
            blk, b = self._pad_block(enc[lo:lo + sb], sb, code.n)
            launched.append((fn(jnp.asarray(blk)), b))
        masks = jax.device_get([m for m, _ in launched])
        flags = np.empty(B, bool)
        lo = 0
        for mask, (_dev, b) in zip(masks, launched, strict=True):
            flags[lo:lo + b] = mask[:b]
            lo += b
        return flags

    def _correct(self, code: LDPCCode, enc: np.ndarray):
        """-> (corrected levels (B, n), flagged, fail) without stats."""
        flagged = self._scan_syndromes(code, enc)
        out = enc.astype(np.int64) % code.p
        fail = np.zeros(enc.shape[0], bool)
        if flagged.any():
            syms, f = self._decode_words(code, enc[flagged])
            out[flagged] = syms
            fail[flagged] = f
        return out, flagged, fail

    # -- policy surface -----------------------------------------------------

    def read(self, code: LDPCCode, store: dict, name: str) -> np.ndarray:
        st = store[name]
        out, flagged, fail = self._correct(code, st.enc)
        n_flagged = int(flagged.sum())
        n_fail = int(fail.sum())
        self.stats.reads += 1
        self.stats.words_read += st.enc.shape[0]
        self.stats.detected += n_flagged
        self.stats.corrected += n_flagged - n_fail
        self.stats.uncorrectable += n_fail
        reg = obs_metrics.current()
        if reg.enabled:
            labels = {"layer": "controller", "policy": self.policy,
                      "code": f"gf{code.p}n{code.n}"}
            reg.counter("mem_words_read", **labels).inc(st.enc.shape[0])
            reg.counter("mem_detected", **labels).inc(n_flagged)
            reg.counter("mem_corrected", **labels).inc(n_flagged - n_fail)
            reg.counter("mem_uncorrectable", **labels).inc(n_fail)
        est = obs_ras.current()
        if est.enabled:
            est.observe_scan(n_flagged, st.enc.shape[0], n_symbols=code.n)
        self._writeback(st, out, flagged, fail)
        return out

    def _writeback(self, st, corrected: np.ndarray, flagged: np.ndarray,
                   fail: np.ndarray) -> None:
        pass                        # basic: never touch storage

    def note_write(self, n_words: int) -> None:
        self.stats.writes += 1
        self.stats.words_written += n_words

    def tick(self, code: LDPCCode, store: dict) -> None:
        pass                        # only the scrub policy acts on ticks

    @staticmethod
    def iter_pages(store: dict,
                   page_words: int | None = None) -> Iterator[np.ndarray]:
        """Yield writable (b, n) row views over the stored words —
        `page_words` rows per page (ragged tails allowed), or one page per
        tensor when None. Repairs written into a page propagate to backing
        storage, so any page iterator with the same contract (e.g. over an
        mmap'd checkpoint archive) can be fed to `scrub_pages` directly."""
        if page_words is not None and page_words <= 0:
            raise ValueError(f"page_words must be positive, got {page_words}")

        def gen():
            for st in store.values():
                enc = st.enc
                if page_words is None:
                    yield enc
                else:
                    for lo in range(0, enc.shape[0], page_words):
                        yield enc[lo:lo + page_words]
        return gen()

    def scrub(self, code: LDPCCode, store: dict, *,
              page_words: int | None = None, coalesce: bool = True) -> dict:
        """Full-array sweep: scan every stored word, repair flagged words in
        place (every policy may be scrubbed explicitly; only
        `ScrubController` does it automatically). `page_words` (default: the
        controller's `page_words`) streams the sweep in fixed-size pages so
        arrays larger than device memory scrub incrementally. Returns a
        report with the sweep's counts, scan bandwidth, and per-page stats."""
        if page_words is None:
            page_words = self.page_words
        return self.scrub_pages(code, self.iter_pages(store, page_words),
                                page_words=page_words, coalesce=coalesce)

    def scrub_pages(self, code: LDPCCode, pages: Iterable[np.ndarray], *,
                    page_words: int | None = None, coalesce: bool = True,
                    scan_ahead: int = 4,
                    drain_words: int | None = None) -> dict:
        """Paged sweep over any iterator of writable (b, n) level-word
        pages: scan each page (host BLAS or the fused device kernel, per
        the resolved kernel policy), batch-decode only the flagged words,
        and write repairs back through the page views. Pages are consumed
        lazily, so arrays larger than device memory stream through.

        `coalesce=True` (default) runs the repair pipeline: pages are
        scanned `scan_ahead` ahead while earlier pages' flagged rows sit on
        the cross-page `RepairQueue`, which drains through bucketed decode
        executables once `drain_words` rows accumulate (one host sync per
        scan window and one per drain, instead of one per page and per
        chunk). `coalesce=False` keeps the per-page scan→pad→decode→sync
        baseline the pipeline is benchmarked against. Both produce
        bit-identical repairs (FBP is row-independent)."""
        if coalesce:
            return self._scrub_pages_coalesced(
                code, pages, page_words=page_words, scan_ahead=scan_ahead,
                drain_words=drain_words)
        return self._scrub_pages_baseline(code, pages, page_words=page_words)

    def _scrub_pages_baseline(self, code: LDPCCode,
                              pages: Iterable[np.ndarray], *,
                              page_words: int | None = None) -> dict:
        """Per-page sweep: one scan sync and one full-`chunk_size` decode
        dispatch train per flagged page (the pre-pipeline behavior)."""
        t0 = time.perf_counter()
        words = flagged_n = corrected_n = fail_n = n_pages = 0
        page_stats = []
        est = obs_ras.current()
        reg = obs_metrics.current()
        for page in pages:
            n_pages += 1
            tp = time.perf_counter()
            # scan-only on clean pages: the full corrected-levels copy that
            # `_correct` builds for reads is skipped, decode touches only
            # flagged rows, and repairs come straight from decoder symbols
            flagged = self._scan_syndromes(code, page)
            pg_flagged = int(flagged.sum())
            pg_fail = 0
            if pg_flagged:
                syms, f = self._decode_words_legacy(code, page[flagged])
                pg_fail = int(f.sum())
                rows = np.flatnonzero(flagged)[~f]
                if rows.size:
                    page[rows] = syms[~f].astype(page.dtype)
            words += page.shape[0]
            flagged_n += pg_flagged
            corrected_n += pg_flagged - pg_fail
            fail_n += pg_fail
            if est.enabled:
                est.observe_scan(pg_flagged, page.shape[0],
                                 n_symbols=code.n)
            if reg.enabled:
                reg.histogram("scrub_page_seconds",
                              layer="controller").observe(
                    time.perf_counter() - tp)
            if n_pages <= MAX_PAGE_STATS:
                page_stats.append({
                    "words": int(page.shape[0]), "flagged": pg_flagged,
                    "corrected": pg_flagged - pg_fail,
                    "uncorrectable": pg_fail,
                    "seconds": time.perf_counter() - tp})
        dt = time.perf_counter() - t0
        self._note_scrub_totals(code, words, corrected_n, fail_n, dt)
        return {"policy": self.policy, "backend": self._scan_route(code),
                "words_scanned": words,
                "cells_scanned": words * code.n, "flagged": flagged_n,
                "corrected": corrected_n, "uncorrectable": fail_n,
                "pages": n_pages, "page_words": page_words,
                "page_stats": page_stats,
                "page_stats_truncated": n_pages > MAX_PAGE_STATS,
                "coalesced": False, "seconds": dt,
                "bandwidth_cells_per_s": words * code.n / dt if dt else 0.0}

    def _scrub_pages_coalesced(self, code: LDPCCode,
                               pages: Iterable[np.ndarray], *,
                               page_words: int | None = None,
                               scan_ahead: int = 4,
                               drain_words: int | None = None) -> dict:
        """The repair pipeline: double-buffered page windows keep scans in
        flight while the previous window's masks resolve in one transfer;
        flagged rows coalesce on the `RepairQueue` across pages and drain
        through bucketed decode executables (again one sync per drain)."""
        t0 = time.perf_counter()
        scan_ahead = max(1, scan_ahead)
        if drain_words is None:
            drain_words = 4 * self.chunk_size
        queue = self._repair_queue(code)
        route = self._scan_route(code)
        fn = self._scanner(code) if route == "device" else None
        est = obs_ras.current()
        reg = obs_metrics.current()
        totals = {"words": 0, "flagged": 0, "corrected": 0,
                  "uncorrectable": 0, "pages": 0}
        page_stats: list[dict] = []
        drain_stats: list[dict] = []

        def flush():
            rep = queue.drain()
            if rep["words"]:
                totals["corrected"] += rep["repaired"]
                totals["uncorrectable"] += rep["failed"]
                drain_stats.append({k: rep[k] for k in (
                    "entries", "words", "repaired", "failed", "pad_rows",
                    "dispatch_rows", "pad_waste", "seconds")})

        def scan_dispatch(page):
            """Dispatch one page's scan without syncing: the device route
            returns in-flight (mask, rows) pairs per scan block; the host
            route computes the np mask eagerly (it never leaves the host)."""
            if fn is None:
                return self._scan_syndromes_host(code, page)
            out = []
            sb = self.scan_block
            for lo in range(0, page.shape[0], sb):
                blk, b = self._pad_block(page[lo:lo + sb], sb, code.n)
                out.append((fn(jnp.asarray(blk)), b))
            return out

        def consume(window):
            """Resolve one scanned window — a single host sync pulls every
            block mask while the next window's scans and any queued decodes
            stay in flight — then enqueue the flagged rows."""
            if not window:
                return
            if fn is not None:
                flat = iter(jax.device_get(
                    [m for _pg, blocks in window for m, _b in blocks]))
            for page, scanned in window:
                if fn is not None:
                    mask = np.empty(page.shape[0], bool)
                    lo = 0
                    for _dev, b in scanned:
                        mask[lo:lo + b] = next(flat)[:b]
                        lo += b
                else:
                    mask = scanned
                totals["pages"] += 1
                totals["words"] += page.shape[0]
                rows = np.flatnonzero(mask)
                pg_flagged = int(rows.size)
                totals["flagged"] += pg_flagged
                if est.enabled:
                    est.observe_scan(pg_flagged, page.shape[0],
                                     n_symbols=code.n)
                slot = None
                if totals["pages"] <= MAX_PAGE_STATS:
                    slot = {"words": int(page.shape[0]),
                            "flagged": pg_flagged, "corrected": 0,
                            "uncorrectable": 0}
                    page_stats.append(slot)
                if not pg_flagged:
                    continue

                def writeback(syms, ok, page=page, rows=rows, slot=slot):
                    good = rows[ok]
                    if good.size:
                        page[good] = syms[ok].astype(page.dtype)
                    if slot is not None:
                        slot["corrected"] = int(ok.sum())
                        slot["uncorrectable"] = int((~ok).sum())

                queue.enqueue(page[rows], writeback,
                              provenance=("page", totals["pages"] - 1, rows))

        prev: list = []
        cur: list = []
        for page in pages:
            cur.append((page, scan_dispatch(page)))
            if len(cur) >= scan_ahead:
                consume(prev)
                prev, cur = cur, []
                if queue.pending_words >= drain_words:
                    flush()
        consume(prev)
        consume(cur)
        flush()
        dt = time.perf_counter() - t0
        words, corrected_n, fail_n = (totals["words"], totals["corrected"],
                                      totals["uncorrectable"])
        self._note_scrub_totals(code, words, corrected_n, fail_n, dt)
        if reg.enabled and drain_stats:
            reg.histogram("scrub_drains_per_sweep",
                          layer="controller").observe(len(drain_stats))
        pad_rows = sum(d["pad_rows"] for d in drain_stats)
        dispatch_rows = sum(d["dispatch_rows"] for d in drain_stats)
        return {"policy": self.policy, "backend": route,
                "words_scanned": words,
                "cells_scanned": words * code.n,
                "flagged": totals["flagged"],
                "corrected": corrected_n, "uncorrectable": fail_n,
                "pages": totals["pages"], "page_words": page_words,
                "page_stats": page_stats,
                "page_stats_truncated": totals["pages"] > MAX_PAGE_STATS,
                "coalesced": True, "scan_ahead": scan_ahead,
                "drains": len(drain_stats), "drain_stats": drain_stats,
                "repair_pad_rows": pad_rows,
                "repair_dispatch_rows": dispatch_rows,
                "repair_pad_waste": (pad_rows / dispatch_rows
                                     if dispatch_rows else 0.0),
                "seconds": dt,
                "bandwidth_cells_per_s": words * code.n / dt if dt else 0.0}

    def _note_scrub_totals(self, code: LDPCCode, words: int, corrected_n: int,
                           fail_n: int, dt: float) -> None:
        """Shared sweep accounting: cumulative `ControllerStats` counters
        plus the metrics-registry export (both sweep flavors report the
        same way)."""
        self.stats.scrub_rounds += 1
        self.stats.scrub_words += words
        self.stats.scrub_cells += words * code.n
        self.stats.scrub_corrected += corrected_n
        self.stats.scrub_uncorrectable += fail_n
        self.stats.scrub_seconds += dt
        reg = obs_metrics.current()
        if reg.enabled:
            labels = {"layer": "controller", "policy": self.policy,
                      "code": f"gf{code.p}n{code.n}"}
            reg.counter("scrub_words_scanned", **labels).inc(words)
            reg.counter("scrub_corrected", **labels).inc(corrected_n)
            reg.counter("scrub_uncorrectable", **labels).inc(fail_n)


class WritebackController(MemoryController):
    """`writeback` policy: reads repair storage as a side effect."""

    policy = "writeback"

    def _writeback(self, st, corrected, flagged, fail):
        ok = flagged & ~fail
        if ok.any():
            st.enc[ok] = corrected[ok].astype(st.enc.dtype)
            self.stats.writebacks += int(ok.sum())


class ScrubController(WritebackController):
    """`scrub` policy: writeback + a background sweep every `interval`
    read/write operations."""

    policy = "scrub"

    def __init__(self, *, interval: int = 16, **kw):
        super().__init__(**kw)
        self.interval = interval
        self._ops = 0

    def tick(self, code: LDPCCode, store: dict) -> None:
        self._ops += 1
        if self._ops % self.interval == 0:
            self.scrub(code, store)


_POLICIES = {"basic": MemoryController, "writeback": WritebackController,
             "scrub": ScrubController}


def make_controller(spec, **kw) -> MemoryController:
    """spec: a policy name ("basic" | "writeback" | "scrub"), a controller
    instance (passed through), or None (basic)."""
    if isinstance(spec, MemoryController):
        return spec
    if spec is None:
        spec = "basic"
    if spec not in _POLICIES:
        raise KeyError(f"unknown controller policy {spec!r}; "
                       f"available: {sorted(_POLICIES)}")
    return _POLICIES[spec](**kw)
