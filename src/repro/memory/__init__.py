"""Protected-memory subsystem: the paper's *memory mode* as a real layer.

- `channel`    — composable MLC memristor channel models (asymmetric level
                 transitions, retention drift, read disturb, stuck-at cells)
                 driven by explicit PRNG keys;
- `array`      — `ProtectedMemoryArray`: tensors packed into GF(p)
                 codewords on write, decoded on read;
- `controller` — pluggable controller policies (basic / writeback / scrub)
                 with per-policy stats;
- `campaign`   — the semi-analytic BER campaign engine (any scheme x any
                 channel), producing the paper-style improvement tables;
- `paged`      — `PagedProtectedStore`: the device-resident backend (pages
                 as jax arrays, device encode/scan, pipelined corrected
                 reads) serving live workloads such as protected KV caches;
- `pool`       — `ProtectedPagePool` / `PooledStore`: the multi-tenant layer
                 (shared ref-counted page pool, block tables, copy-on-write
                 aliasing, cold-page background scrub);
- `repair`     — `RepairQueue`: the coalescing repair pipeline (cross-page/
                 store/tenant flagged-row batching into power-of-two
                 bucketed decode executables, one host sync per drain);
- `packing`    — the byte<->GF(p) symbolization shared by both backends.
"""
from .array import (ProtectedMemoryArray, StoredTensor, symbolize_bytes,
                    desymbolize_bytes, digits_per_byte)
from .paged import (PagedProtectedStore, QuantizedTensor, quantize_tensor,
                    dequantize_tensor, words_for_tensor)
from .pool import PoolExhausted, ProtectedPagePool, PooledStore
from .channel import (Channel, LevelTransition, RetentionDrift, ReadDisturb,
                      StuckAt, Compose, PlusMinusOne, uniform_flip,
                      asymmetric_adjacent, validate_transition)
from .controller import (ControllerStats, MemoryController,
                         WritebackController, ScrubController,
                         make_controller)
from .repair import RepairQueue, bucket_sizes
from .campaign import (ResidualProfile, NBLDPCScheme, HammingSECDEDScheme,
                       ModuloParityScheme, UnprotectedScheme, binom_pmf,
                       conditional_residual_profile, post_ber_from_profile,
                       run_campaign, paper_schemes, select_acceptance_row)

__all__ = [
    "ProtectedMemoryArray", "StoredTensor", "symbolize_bytes",
    "desymbolize_bytes", "digits_per_byte",
    "PagedProtectedStore", "QuantizedTensor", "quantize_tensor",
    "dequantize_tensor", "words_for_tensor",
    "PoolExhausted", "ProtectedPagePool", "PooledStore",
    "Channel", "LevelTransition", "RetentionDrift", "ReadDisturb", "StuckAt",
    "Compose", "PlusMinusOne", "uniform_flip", "asymmetric_adjacent",
    "validate_transition",
    "ControllerStats", "MemoryController", "WritebackController",
    "ScrubController", "make_controller",
    "RepairQueue", "bucket_sizes",
    "ResidualProfile", "NBLDPCScheme", "HammingSECDEDScheme",
    "ModuloParityScheme", "UnprotectedScheme", "binom_pmf",
    "conditional_residual_profile", "post_ber_from_profile", "run_campaign",
    "paper_schemes", "select_acceptance_row",
]
