"""`ProtectedMemoryArray`: NB-LDPC-protected tensor storage (memory mode).

This is the **host packing backend** of the protected-store stack: tensors
live as numpy codeword arrays, encode runs on the host BLAS path, and reads
decode synchronously under a controller policy. It is the right backend for
checkpoints and cold storage; live serving workloads use the device-resident
`repro.memory.paged.PagedProtectedStore`, which keeps pages as jax arrays,
encodes on device, and streams corrected reads so decode overlaps the
consumer.

Arbitrary tensors are packed into GF(p) codewords on write — bytes are
symbolized as base-p digits (6 trits/byte for GF(3), vs the 8 binary-valued
trits/byte of the original checkpoint hack: 25% fewer cells; see
`repro.memory.packing`, shared with the device backend) and encoded with the
framework's own systematic code — and decoded on read through the vectorized
`repro.core.decode` engine, under a pluggable controller policy
(`repro.memory.controller`). Device faults are injected through the
`repro.memory.channel` models, never by hand-editing stored words.

    mem = ProtectedMemoryArray(code="wl1024_r08", controller="writeback")
    mem.write("kv", kv_cache)
    mem.inject(asymmetric_adjacent(3, 1e-3, 5e-4), key=0)
    kv = mem.read("kv")                   # corrected transparently
    mem.controller.stats.corrected       # accounting per policy
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_code, np_encode_words
from repro.core.construction import LDPCCode

from .channel import Channel
from .controller import MemoryController, make_controller
from .packing import digits_per_byte, symbolize_bytes, desymbolize_bytes

__all__ = ["ProtectedMemoryArray", "StoredTensor", "symbolize_bytes",
           "desymbolize_bytes", "digits_per_byte"]


@dataclasses.dataclass
class StoredTensor:
    """One tensor's protected representation: (n_words, n) cell levels."""

    enc: np.ndarray                # (n_words, n) levels in [0, p), int8
    dtype: str
    shape: tuple
    nbytes: int


class ProtectedMemoryArray:
    """A named store of tensors held as NB-LDPC codewords of one code."""

    def __init__(self, code: str | LDPCCode = "wl1024_r08", *,
                 controller: str | MemoryController | None = "basic",
                 channel: Channel | None = None, key: int = 0, **ctrl_kw):
        self.code = get_code(code) if isinstance(code, str) else code
        self.controller = make_controller(controller, **ctrl_kw)
        self.channel = channel
        self._store: dict[str, StoredTensor] = {}
        self._key = jax.random.PRNGKey(key)
        self._injections = 0

    # -- introspection ------------------------------------------------------

    @property
    def names(self):
        return sorted(self._store)

    @property
    def stats(self):
        return self.controller.stats

    def stored(self, name: str) -> StoredTensor:
        return self._store[name]

    def import_stored(self, name: str, st: StoredTensor) -> None:
        """Adopt an externally persisted protected tensor (checkpoint
        restore path) without re-encoding."""
        self._store[name] = StoredTensor(
            np.asarray(st.enc, np.int8), str(st.dtype), tuple(st.shape),
            int(st.nbytes))

    def discard(self, name: str) -> None:
        """Drop a tensor's stored codewords (streaming save/restore keeps
        one leaf resident at a time instead of the whole checkpoint)."""
        self._store.pop(name, None)

    def n_words(self) -> int:
        return sum(st.enc.shape[0] for st in self._store.values())

    # -- write / read -------------------------------------------------------

    def write(self, name: str, array) -> StoredTensor:
        arr = np.asarray(array)
        raw = arr.tobytes()
        code = self.code
        syms = symbolize_bytes(raw, code.p)
        pad = (-syms.size) % code.k
        words = np.pad(syms, (0, pad)).reshape(-1, code.k)
        enc = np_encode_words(words, code).astype(np.int8)
        st = StoredTensor(enc, str(arr.dtype), arr.shape, len(raw))
        self._store[name] = st
        self.controller.note_write(enc.shape[0])
        self.controller.tick(code, self._store)
        return st

    def read(self, name: str, *, correct: bool = True) -> np.ndarray:
        st = self._store[name]
        if correct:
            levels = self.controller.read(self.code, self._store, name)
        else:
            levels = st.enc.astype(np.int64) % self.code.p
        syms = levels[:, :self.code.k].reshape(-1)
        raw = desymbolize_bytes(syms, st.nbytes, self.code.p)
        # frombuffer over `bytes` is a read-only view; hand back a writable
        # copy so callers can mutate what they read (it's their tensor).
        arr = np.frombuffer(raw, dtype=np.dtype(st.dtype)).reshape(st.shape)
        out = arr.copy()
        self.controller.tick(self.code, self._store)
        return out

    # -- fault injection / maintenance --------------------------------------

    def inject(self, channel: Channel | None = None,
               key: int | jax.Array | None = None, *, t: float = 0.0,
               n_reads: int = 0) -> int:
        """Corrupt the stored words in place through a channel model. `key`
        is a PRNG key or a plain int seed. Returns the number of cells
        actually changed. Each call folds a fresh sub-key, so repeated
        injections accumulate (aging)."""
        ch = channel if channel is not None else self.channel
        if ch is None:
            raise ValueError("no channel: pass one or construct the array "
                             "with channel=...")
        if ch.domain != "level":
            raise ValueError(f"{type(ch).__name__} is an integer-domain "
                             "channel; stored cells need a level-domain one")
        if ch.p != self.code.p:
            raise ValueError(f"channel alphabet {ch.p} != GF({self.code.p})")
        if key is None:
            key = jax.random.fold_in(self._key, self._injections)
        elif isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self._injections += 1
        changed = 0
        for i, name in enumerate(self.names):
            st = self._store[name]
            k = jax.random.fold_in(key, i)
            new = np.asarray(ch.apply(k, jnp.asarray(st.enc, jnp.int32),  # noqa: RPL007 - fault-injection utility, not a hot path; storage is host numpy
                                      t=t, n_reads=n_reads), np.int8)
            changed += int((new != st.enc).sum())
            st.enc = new
        return changed

    def iter_pages(self, page_words: int | None = None):
        """Writable (b, n) pages over the stored words (`page_words` rows
        per page; one page per tensor when None) — the streaming surface
        for `scrub_pages` and external scrub services."""
        return self.controller.iter_pages(self._store, page_words)

    def scrub(self, *, page_words: int | None = None, **kw) -> dict:
        """Explicit full sweep (any policy): scan + repair storage.
        `page_words` streams the sweep in fixed-size pages (incremental
        scrubbing for arrays larger than device memory). Extra keywords
        (`coalesce=`, `scan_ahead=`, `drain_words=`) reach
        `MemoryController.scrub_pages` — the coalescing repair pipeline is
        the default; `coalesce=False` keeps the per-page baseline."""
        return self.controller.scrub(self.code, self._store,
                                     page_words=page_words, **kw)

    def scrub_pages(self, pages, **kw) -> dict:
        """Sweep an explicit page iterator (see `iter_pages`) — the hook
        for scrubbing external storage through this array's code and
        controller."""
        return self.controller.scrub_pages(self.code, pages, **kw)
