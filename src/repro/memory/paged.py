"""`PagedProtectedStore`: the device-resident protected-store backend.

Where `repro.memory.array.ProtectedMemoryArray` (the host packing backend)
holds numpy codewords and decodes whole tensors synchronously — right for
checkpoints — this backend keeps storage as fixed-shape **(page_words, n)
GF-level pages living as jax arrays**, so protection can sit under live
workloads:

- **encode on device** — appended info words run through
  `repro.kernels.ops.encode_words` (the Pallas `gf_matmul` MXU path with the
  mod-p fused epilogue); one cached (page_words, k) executable serves every
  append, and pages never round-trip through the host;
- **scan on device** — per-page syndrome flagging via the fused
  `scan_syndromes` kernel (only the (page_words,) mask leaves the device);
- **streaming corrected reads** — `iter_corrected()` walks the pages through
  `repro.core.protected.decode_pipelined`: page *i+1*'s decode is dispatched
  before page *i* is yielded, so decode latency hides behind the consumer
  (attention, in the protected KV-serving path). Clean pages (no flags) skip
  the decoder entirely.

With `mesh` set, pages are shard_map'd across the local devices row-wise
(`decode_sharded` / `scan_syndromes_sharded`), alongside the batch axis the
rest of the stack already shards.

`quantize_tensor` / `dequantize_tensor` are the jittable float<->GF bridges
used by the protected KV cache (`repro.models.kv`): absmax int8 quantization,
then base-p symbolization (`repro.memory.packing`, shared with the host
backend so device pages and host checkpoints interoperate bit-exactly).
"""
from __future__ import annotations

import dataclasses
import math
from collections.abc import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_code
from repro.core.construction import LDPCCode
from repro.core.decode import decode_integers
from repro.core.protected import decode_pipelined, np_prod_mesh
from repro.obs import metrics as obs_metrics
from repro.obs import ras as obs_ras

from .channel import Channel
from .controller import ControllerStats
from .packing import digits_per_byte, symbolize_u8, desymbolize_u8

__all__ = ["PagedProtectedStore", "QuantizedTensor", "quantize_tensor",
           "dequantize_tensor", "words_for_tensor"]


# ---------------------------------------------------------------------------
# float tensor <-> info words (jittable; the KV-cache quantization bridge)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Metadata needed to reassemble a tensor from its info words."""

    shape: tuple
    dtype: str
    scale: jnp.ndarray          # () float32 absmax scale
    n_words: int                # info words the tensor occupies


def words_for_tensor(shape, p: int, k: int) -> int:
    """Info words an int8-quantized tensor of `shape` packs into."""
    numel = int(np.prod(shape)) if shape else 1
    return math.ceil(numel * digits_per_byte(p) / k) if numel else 0


def quantize_tensor(x: jnp.ndarray, p: int, k: int
                    ) -> tuple[jnp.ndarray, QuantizedTensor]:
    """absmax-int8 quantize + symbolize + pack: float tensor -> ((m, k) info
    words in [0, p), QuantizedTensor meta). Pure jnp (a handful of
    elementwise dispatches — the encode/decode executables dominate the
    page path). Padding digits are zero (they decode to bytes that are
    sliced off)."""
    shape, dtype = tuple(x.shape), str(x.dtype)
    xf = x.astype(jnp.float32).reshape(-1)
    absmax = jnp.max(jnp.abs(xf)) if xf.size else jnp.float32(0)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    u8 = q + 128                                   # [1, 255] byte values
    digits = symbolize_u8(u8, p).reshape(-1)       # (numel * D,)
    k = int(k)
    m = words_for_tensor(shape, p, k)
    pad = m * k - digits.shape[0]
    if pad:
        digits = jnp.concatenate([digits, jnp.zeros(pad, digits.dtype)])
    return digits.reshape(m, k), QuantizedTensor(shape, dtype, scale, m)


def dequantize_tensor(words: jnp.ndarray, meta: QuantizedTensor,
                      p: int) -> jnp.ndarray:
    """Inverse bridge: (m, k) decoded info words -> tensor of `meta.shape`.
    Corrupted-but-uncorrected symbols degrade to wrong values, never
    crashes (digits are clipped into the field)."""
    numel = int(np.prod(meta.shape)) if meta.shape else 1
    D = digits_per_byte(p)
    digits = words.reshape(-1)[:numel * D].reshape(numel, D)
    u8 = desymbolize_u8(digits, p)
    q = u8.astype(jnp.float32) - 128.0
    out = (q * meta.scale).astype(meta.dtype)
    return out.reshape(meta.shape)


# ---------------------------------------------------------------------------
# the device-resident paged store
# ---------------------------------------------------------------------------


class PagedProtectedStore:
    """Fixed-shape (page_words, n) GF-level pages as jax arrays, with device
    encode, per-page syndrome flagging, and pipelined corrected reads."""

    def __init__(self, code: str | LDPCCode = "wl1024_r08", *,
                 page_words: int = 256, mesh=None, n_iters: int = 10,
                 damping: float = 0.3, llv_scale: float = 4.0,
                 llv_mode: str = "manhattan", key: int = 0,
                 policy=None):
        self.code = get_code(code) if isinstance(code, str) else code
        # The device encode/scan executables accumulate int32: every
        # dot-product term is a product of two symbols in [0, p), so the
        # per-word sum is bounded by n*(p-1)^2 and must stay below 2^31.
        # Codes past that belong on MemoryController's exact int64 host
        # path — reject them here rather than wrap silently in the kernel.
        if self.code.n * (self.code.p - 1) ** 2 >= 2 ** 31:
            raise ValueError(
                f"code n={self.code.n} p={self.code.p} exceeds the int32 "
                "kernel accumulator bound n*(p-1)^2 < 2^31; use "
                "MemoryController's exact host scan for this code")
        # Backend selection is one KernelPolicy (repro.kernels.backend):
        # None defers to the ambient policy at executable-build time —
        # "auto" compiles the Pallas kernels natively on TPU and routes to
        # the jitted jnp oracles elsewhere (bit-identical by the kernel
        # parity tests); interpret-mode is the CPU correctness path.
        if policy is not None:
            from repro.kernels.backend import _as_policy
            policy = _as_policy(policy)
        self.policy = policy
        if page_words <= 0:
            raise ValueError(f"page_words must be positive, got {page_words}")
        if mesh is not None:
            mesh_size = np_prod_mesh(mesh)
            if page_words % mesh_size != 0:
                raise ValueError(
                    f"page_words={page_words} is not a multiple of the mesh "
                    f"size {mesh_size}; pages are shard_map'd row-wise, so "
                    "pick a page size divisible by the device count")
        self.page_words = page_words
        self.mesh = mesh
        self.n_iters = n_iters
        self.damping = damping
        self.llv_scale = llv_scale
        self.llv_mode = llv_mode
        self._pages: list = []            # [(page_words, n) int32 jax arrays]
        self._new_page = lambda: jnp.zeros((page_words, self.code.n),
                                           jnp.int32)
        if mesh is not None:
            from repro.distributed.sharding import shard_page
            base = self._new_page
            self._new_page = lambda: shard_page(base(), mesh)
        self._n_words = 0                 # valid words across pages
        self._key = jax.random.PRNGKey(key)
        self._injections = 0
        self._encode_fn = None
        self._scan_fn = None
        self._decode_fn = None
        self._repair_q = None
        # read/scrub correction accounting (per-store, so a serving layer can
        # attribute corrections to the tenant that owns the store)
        self.stats = ControllerStats()

    # -- introspection ------------------------------------------------------

    @property
    def n_words(self) -> int:
        return self._n_words

    @property
    def n_pages(self) -> int:
        return len(self._pages)

    @property
    def n_cells(self) -> int:
        return self._n_words * self.code.n

    def page(self, i: int) -> jnp.ndarray:
        return self._pages[i]

    # -- storage indirection -------------------------------------------------
    # All page reads/writes go through these four primitives. The standalone
    # store owns a plain list of jax arrays; `repro.memory.pool.PooledStore`
    # overrides them to address a shared ref-counted page pool through a
    # per-tenant block table instead.

    def _set_page(self, i: int, page: jnp.ndarray) -> None:
        self._pages[i] = page

    def _append_page(self) -> None:
        """Grow storage by one zeroed page."""
        self._pages.append(self._new_page())

    def _iter_pages(self) -> Iterator[jnp.ndarray]:
        for i in range(self.n_pages):
            yield self.page(i)

    def free(self) -> None:
        """Release all storage (pool-backed stores return their pages to the
        shared free list; the standalone store just drops them)."""
        self._pages.clear()
        self._n_words = 0

    # -- cached executables -------------------------------------------------

    def _mode(self) -> str:
        """Resolved kernel mode: the store's pinned policy, else the
        ambient one — sampled when a cached executable is (re)built."""
        from repro.kernels.backend import current_policy
        return (self.policy or current_policy()).resolve()

    def _use_kernels(self) -> bool:
        return self._mode() != "ref"

    def _encoder(self):
        """One cached (page_words, k) device-encode executable: the Pallas
        `encode_words` MXU path on TPU, its jitted jnp oracle elsewhere.
        The resolved mode is baked in at build time (the interpret flag is
        passed explicitly so a later ambient-policy change can't silently
        retarget a cached trace)."""
        if self._encode_fn is None:
            P = jnp.asarray(self.code.P, jnp.int32)
            p = self.code.p
            mode = self._mode()
            if mode != "ref":
                from repro.kernels.ops import encode_words
                interp = mode == "interpret"
                self._encode_fn = jax.jit(
                    lambda u: encode_words(u, P, p, interpret=interp))
            else:
                from repro.kernels.ref import encode_words_ref
                self._encode_fn = jax.jit(
                    lambda u: encode_words_ref(u, P, p))
        return self._encode_fn

    def _scanner(self):
        """One cached (page_words, n) syndrome-scan executable (fused Pallas
        kernel on TPU, jnp oracle elsewhere; sharded over `mesh` when
        given)."""
        if self._scan_fn is None:
            if self.mesh is not None:
                from repro.distributed.sharding import scan_syndromes_sharded
                code, mesh = self.code, self.mesh
                self._scan_fn = jax.jit(
                    lambda y: scan_syndromes_sharded(code, y, mesh=mesh))
            else:
                ht = jnp.asarray(self.code.H.T, jnp.int32)
                p = self.code.p
                mode = self._mode()
                if mode != "ref":
                    from repro.kernels.ops import scan_syndromes
                    interp = mode == "interpret"
                    self._scan_fn = jax.jit(
                        lambda y: scan_syndromes(y, ht, p, interpret=interp))
                else:
                    from repro.kernels.ref import scan_syndromes_ref
                    self._scan_fn = jax.jit(
                        lambda y: scan_syndromes_ref(y, ht, p))
        return self._scan_fn

    def _decoder(self):
        """One cached (page_words, n) decode executable (sharded over
        `mesh` when given)."""
        if self._decode_fn is None:
            code = self.code
            kw = dict(n_iters=self.n_iters, damping=self.damping,
                      llv_scale=self.llv_scale, llv_mode=self.llv_mode,
                      early_exit=True)
            if self.mesh is not None:
                from repro.distributed.sharding import decode_sharded
                mesh = self.mesh
                self._decode_fn = jax.jit(
                    lambda y: decode_sharded(code, y, mesh=mesh, **kw))
            else:
                self._decode_fn = jax.jit(
                    lambda y: decode_integers(code, y, **kw))
        return self._decode_fn

    def _repair_queue(self):
        """The coalescing repair queue this store's scrubs drain through
        (cross-page flagged-row batching; see `repro.memory.repair`).
        `PooledStore` delegates to the pool template's queue, so every
        tenant of a pool shares one queue — and one coalesced drain.

        Serving-facing stores pin a SINGLE decode bucket
        (`min_bucket=page_words`): a drain here is at most a few pages'
        sparse flags, so the bucket ladder could only trade pad rows
        (microseconds) for extra jit compiles (~seconds each) that land as
        p99 spikes inside serving steps. The controller's scrub-daemon
        queue keeps the full power-of-two ladder, where sweep shapes are
        stable and bucketing pays."""
        if self._repair_q is None:
            from .repair import RepairQueue
            self._repair_q = RepairQueue(
                self.code, chunk_size=self.page_words,
                min_bucket=self.page_words,
                n_iters=self.n_iters, damping=self.damping,
                llv_scale=self.llv_scale, llv_mode=self.llv_mode)
        return self._repair_q

    # -- write path ---------------------------------------------------------

    def _encode_rows(self, u: jnp.ndarray) -> jnp.ndarray:
        """Encode (b, k) info rows through the fixed-shape executable."""
        b = u.shape[0]
        if b < self.page_words:
            u = jnp.concatenate(
                [u, jnp.zeros((self.page_words - b, u.shape[1]), u.dtype)])
        return self._encoder()(u.astype(jnp.int32))[:b]

    def append_words(self, u) -> tuple[int, int]:
        """Append (m, k) info words (field symbols in [0, p)): encode on
        device and pack into pages. Returns the occupied word range
        [start, start + m). A partially-filled trailing page is padded with
        all-zero words (valid codewords — scan-neutral) and topped up by the
        next append."""
        u = jnp.asarray(u)
        if u.ndim != 2 or u.shape[1] != self.code.k:
            raise ValueError(f"expected (m, {self.code.k}) info words, got "
                             f"{tuple(u.shape)}")
        m = u.shape[0]
        start = self._n_words
        pw = self.page_words
        done = 0
        while done < m:
            slot = self._n_words % pw
            if slot == 0:
                self._append_page()
            take = min(m - done, pw - slot)
            enc = self._encode_rows(u[done:done + take])
            last = self.n_pages - 1
            self._set_page(last, jax.lax.dynamic_update_slice(
                self.page(last), enc, (slot, 0)))
            done += take
            self._n_words += take
        self.stats.writes += 1
        self.stats.words_written += m
        return start, start + m

    def append_encoded(self, enc) -> tuple[int, int]:
        """Adopt already-encoded (m, n) codewords (e.g. host-encoded
        checkpoint pages from `ProtectedMemoryArray.stored`) without
        re-encoding — the backend-interop path."""
        enc = jnp.asarray(enc, jnp.int32)
        if enc.ndim != 2 or enc.shape[1] != self.code.n:
            raise ValueError(f"expected (m, {self.code.n}) codewords, got "
                             f"{tuple(enc.shape)}")
        m = enc.shape[0]
        start = self._n_words
        pw = self.page_words
        done = 0
        while done < m:
            slot = self._n_words % pw
            if slot == 0:
                self._append_page()
            take = min(m - done, pw - slot)
            last = self.n_pages - 1
            self._set_page(last, jax.lax.dynamic_update_slice(
                self.page(last), enc[done:done + take], (slot, 0)))
            done += take
            self._n_words += take
        self.stats.writes += 1
        self.stats.words_written += m
        return start, start + m

    def export_words(self) -> np.ndarray:
        """All valid stored codewords as one host (n_words, n) int8 array
        (checkpoint hand-off to the host backend)."""
        if not self.n_pages:
            return np.zeros((0, self.code.n), np.int8)
        # one transfer for the whole store, not one per page
        flat = np.concatenate(jax.device_get(list(self._iter_pages())))
        return flat[:self._n_words].astype(np.int8)

    # -- fault injection ----------------------------------------------------

    def inject(self, channel: Channel,
               key: int | jax.Array | None = None, *, t: float = 0.0,
               n_reads: int = 0) -> int:
        """Corrupt the stored pages in place through a level-domain channel
        model (device-side). Returns the number of cells changed. Pad rows
        of the trailing page are corrupted too — they are storage like any
        other row, and the scan/decode path treats their errors normally."""
        if channel.domain != "level":
            raise ValueError(f"{type(channel).__name__} is an integer-domain "
                             "channel; stored cells need a level-domain one")
        if channel.p != self.code.p:
            raise ValueError(f"channel alphabet {channel.p} != "
                             f"GF({self.code.p})")
        if key is None:
            key = jax.random.fold_in(self._key, self._injections)
        elif isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self._injections += 1
        changed = 0
        for i in range(self.n_pages):
            page = self.page(i)
            k = jax.random.fold_in(key, i)
            new = channel.apply(k, page, t=t, n_reads=n_reads)
            new = new.astype(jnp.int32)
            changed += int(jnp.sum(new != page))
            self._set_page(i, new)
        return changed

    # -- read path ----------------------------------------------------------

    def scan_flags(self) -> np.ndarray:
        """(n_words,) bool — per-word nonzero-syndrome flags via the fused
        device scan, streamed page by page through one executable."""
        if not self.n_pages:
            return np.zeros(0, bool)
        fn = self._scanner()
        # dispatch every page's scan, then pull all masks in one sync
        flags = np.concatenate(
            jax.device_get([fn(pg) for pg in self._iter_pages()]))
        return flags[:self._n_words]

    def iter_corrected(self, *, scan_first: bool = True,
                       depth: int = 1) -> Iterator[jnp.ndarray]:
        """Yield (page_words, n) corrected symbol pages in storage order,
        double-buffered: page i+1's scan/decode is dispatched before page i
        is yielded, so decode overlaps the consumer. With `scan_first`,
        clean pages bypass the decoder entirely (the serving fast path:
        scan is one fused matmul; FBP runs only where the scan flags)."""
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        scan = self._scanner() if scan_first else None
        decode = self._decoder()

        def dispatch(page):
            if scan is not None:
                nf = int(np.asarray(scan(page)).sum())
                if not nf:
                    return page                   # clean: levels ARE symbols
                self.stats.detected += nf
            _y, res = decode(page)                # async dispatch
            return res.symbols

        pending = []
        for page in self._iter_pages():
            self.stats.reads += 1
            self.stats.words_read += self.page_words
            pending.append(dispatch(page))
            if len(pending) > depth:
                yield pending.pop(0)
        yield from pending

    def read_page_corrected(self, i: int) -> jnp.ndarray:
        """Scan-gated synchronous corrected read of page `i`, with full
        correction accounting on `self.stats` (detected / corrected /
        uncorrectable). The per-page primitive the serving engine uses to
        attribute corrections to the tenant owning this store."""
        page = self.page(i)
        self.stats.reads += 1
        self.stats.words_read += self.page_words
        flags = np.asarray(self._scanner()(page))
        nf = int(flags.sum())
        est = obs_ras.current()
        owner = getattr(self, "owner", None)
        region = str(owner) if owner is not None else ""
        if est.enabled:
            est.observe_scan(nf, self.page_words, n_symbols=self.code.n,
                             region=region)
        if not nf:
            return page
        self.stats.detected += nf
        _y, res = self._decoder()(page)
        bad = int((flags & np.asarray(res.detect_fail)).sum())
        self.stats.uncorrectable += bad
        self.stats.corrected += nf - bad
        reg = obs_metrics.current()
        if reg.enabled:
            lab = {"layer": "paged", "tenant": region,
                   "code": f"gf{self.code.p}n{self.code.n}"}
            reg.counter("mem_detected", **lab).inc(nf)
            reg.counter("mem_corrected", **lab).inc(nf - bad)
            reg.counter("mem_uncorrectable", **lab).inc(bad)
        if est.enabled:
            iters = getattr(res, "iterations", None)
            if iters is not None:
                est.observe_decode(iters, self.n_iters,
                                   detect_fail=res.detect_fail,
                                   region=region)
        return res.symbols

    def read_corrected(self) -> jnp.ndarray:
        """Synchronous whole-store corrected read: every page decoded and
        stacked to (n_words, n) symbols. The baseline the pipelined read is
        benchmarked against."""
        if not self.n_pages:
            return jnp.zeros((0, self.code.n), jnp.int32)
        decode = self._decoder()
        outs = [decode(pg)[1].symbols for pg in self._iter_pages()]
        return jnp.concatenate(outs)[:self._n_words]

    def read_words(self, start: int, stop: int, *,
                   corrected: bool = True) -> jnp.ndarray:
        """Gather stored words [start, stop) across pages (corrected via the
        per-page scan+decode route, or raw levels)."""
        if not 0 <= start <= stop <= self._n_words:
            raise ValueError(f"word range [{start}, {stop}) outside "
                             f"[0, {self._n_words})")
        if start == stop:
            return jnp.zeros((0, self.code.n), jnp.int32)
        pw = self.page_words
        out = []
        for pi in range(start // pw, (stop - 1) // pw + 1):
            page = (self.read_page_corrected(pi) if corrected
                    else self.page(pi))
            lo = max(start - pi * pw, 0)
            hi = min(stop - pi * pw, pw)
            out.append(page[lo:hi])
        return jnp.concatenate(out)

    def read_info(self, start: int, stop: int, *,
                  corrected: bool = True) -> jnp.ndarray:
        """Like `read_words` but sliced to the (m, k) info symbols — the
        shape `dequantize_tensor` consumes."""
        return self.read_words(start, stop, corrected=corrected)[:, :self.code.k]

    def decode_stream(self, **kw) -> Iterator:
        """The raw `(y_corrected, DecodeResult)` pipeline over the stored
        pages (see `repro.core.protected.decode_pipelined`) for consumers
        that need decode metadata (detect_fail, iterations) per page."""
        kw.setdefault("chunk_size", self.page_words)
        kw.setdefault("n_iters", self.n_iters)
        kw.setdefault("damping", self.damping)
        kw.setdefault("llv_scale", self.llv_scale)
        kw.setdefault("llv_mode", self.llv_mode)
        kw.setdefault("mesh", self.mesh)
        return decode_pipelined(self.code, self._iter_pages(), **kw)

    def scrub(self, pages=None, *, coalesce: bool = True) -> dict:
        """Sweep the pages: scan, repair flagged words, write back
        (device-side). `pages` optionally restricts the sweep to a subset of
        page indices (the engine's cold-page background scrub). Returns
        {pages, flagged_words, repaired_words}.

        `coalesce=True` (default) runs the repair pipeline: every page's
        scan is dispatched before any mask is pulled (one sync for the
        sweep), flagged rows are gathered on device and coalesced across
        pages on the `RepairQueue`, and one bucketed drain repairs them —
        sparse flags pay a bucket-sized FBP instead of a whole-page one.
        `coalesce=False` keeps the per-page scan→whole-page-decode baseline
        (bit-identical repairs; FBP is row-independent)."""
        idxs = list(range(self.n_pages) if pages is None else pages)
        if coalesce:
            report = self._scrub_coalesced(idxs)
        else:
            report = self._scrub_baseline(idxs)
        self.stats.scrub_rounds += 1
        self.stats.scrub_words += report["pages"] * self.page_words
        self.stats.scrub_corrected += report["repaired_words"]
        self.stats.scrub_uncorrectable += (report["flagged_words"]
                                           - report["repaired_words"])
        return report

    def _scrub_baseline(self, idxs: list[int]) -> dict:
        """Per-page sweep: sync each page's flag count, decode the whole
        page when any row flags (the pre-pipeline behavior)."""
        scan, decode = self._scanner(), self._decoder()
        flagged_words = repaired = swept = 0
        for i in idxs:
            page = self.page(i)
            swept += 1
            flags = scan(page)
            nf = int(jnp.sum(flags))
            if not nf:
                continue
            flagged_words += nf
            _y, res = decode(page)
            good = flags & ~res.detect_fail
            self._set_page(i, jnp.where(good[:, None], res.symbols, page))
            repaired += int(jnp.sum(good))
        return {"pages": swept, "flagged_words": flagged_words,
                "repaired_words": repaired, "coalesced": False}

    def _scrub_coalesced(self, idxs: list[int]) -> dict:
        """Pipelined sweep: dispatch all scans, one mask sync, pull the
        flagged pages whole in a second batched sync, one coalesced
        bucketed drain. Rows are sliced and repaired on host page copies
        so every device op stays page- or bucket-shaped — per-flag-count
        gathers/scatters would recompile on every new count."""
        if not idxs:
            return {"pages": 0, "flagged_words": 0, "repaired_words": 0,
                    "coalesced": True}
        scan = self._scanner()
        masks = jax.device_get([scan(self.page(i)) for i in idxs])
        queue = self._repair_queue()
        owner = getattr(self, "owner", None)
        flagged_words = 0
        flagged = [(i, rows) for i, mask in zip(idxs, masks, strict=True)
                   if (rows := np.flatnonzero(mask)).size]
        pages = jax.device_get([self.page(i) for i, _ in flagged])
        for (i, rows), arr in zip(flagged, pages, strict=True):
            arr = np.array(arr)        # device_get views can be read-only
            flagged_words += int(rows.size)

            def writeback(syms, ok, i=i, rows=rows, arr=arr):
                good = rows[ok]
                if good.size:
                    arr[good] = syms[ok].astype(arr.dtype)
                    self._set_page(i, jnp.asarray(arr, jnp.int32))

            queue.enqueue(arr[rows], writeback, owner=owner,
                          provenance=("store", i, rows))
        rep = queue.drain()
        return {"pages": len(idxs), "flagged_words": flagged_words,
                "repaired_words": rep["repaired"], "coalesced": True,
                "drain": {k: rep[k] for k in (
                    "entries", "words", "repaired", "failed", "pad_rows",
                    "dispatch_rows", "pad_waste", "seconds")}}
