"""Semi-analytic BER campaign engine (library-grade, promoted out of
`benchmarks/ber_common.py`).

Direct Monte-Carlo at raw BER 1e-5 would need ~10^8 decoded symbols to see a
single residual error, so we use the standard semi-analytic decomposition

    post_BER(eps) = sum_m  Binom(n, eps, m) * r(m)

where r(m) = E[fraction of cells still wrong after decoding | exactly m
injected cell errors], estimated by conditional Monte-Carlo per m. This is
exact in expectation, covers every raw BER with ONE set of decode runs, and
matches how the paper's own low-BER points must have been produced (their
Fig. 6 reaches 1.7e-7).

The engine runs **any scheme** (NB-LDPC via the vectorized decode engine,
the `repro.core.baselines` Hamming SECDED and modulo-parity baselines, or
an unprotected reference) against **any channel model**
(`repro.memory.channel`): a scheme owns its cell geometry (`n_cells` stored
cells per codeword, `n_info` of them data) and reports conditional
residuals over both the whole codeword and the info cells — the paper's
figures quote *data* BER, so comparisons use the info-cell residuals.
"""
from __future__ import annotations

import dataclasses
import math
from collections.abc import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encode_words
from repro.core.baselines import HammingSECDED, ModuloParity
from repro.core.construction import LDPCCode
from repro.core.decode import decode_integers

from .channel import Channel, PlusMinusOne

__all__ = [
    "ResidualProfile", "NBLDPCScheme", "HammingSECDEDScheme",
    "ModuloParityScheme", "UnprotectedScheme", "binom_pmf",
    "conditional_residual_profile", "post_ber_from_profile", "run_campaign",
    "paper_schemes", "select_acceptance_row",
]


# ---------------------------------------------------------------------------
# residual profiles + the binomial mix
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ResidualProfile:
    """Conditional residuals r(m) for m = 0..max_errors of one scheme."""

    name: str
    n_cells: int                      # stored cells per codeword (binomial n)
    n_info: int                       # info cells among them
    r_word: np.ndarray                # residual over all n_cells
    r_info: np.ndarray                # residual over the n_info data cells
    detected: np.ndarray | None = None   # detection coverage per m, if any


def binom_pmf(n: int, eps: float, m: int) -> float:
    if eps <= 0:
        return 1.0 if m == 0 else 0.0
    logp = (math.lgamma(n + 1) - math.lgamma(m + 1) - math.lgamma(n - m + 1)
            + m * math.log(eps) + (n - m) * math.log1p(-eps))
    return math.exp(logp)


def mix_post_ber(n_cells: int, r: np.ndarray, eps: float) -> float:
    """Binomial mix of conditional residuals; the probability mass beyond
    max_errors is charged as a decoder-gives-up upper bound (2*eps residual,
    the convention the committed Fig. 6 benches were produced with)."""
    total = 0.0
    for m in range(1, len(r)):
        total += binom_pmf(n_cells, eps, m) * r[m]
    tail = 1.0 - sum(binom_pmf(n_cells, eps, m) for m in range(len(r)))
    total += max(tail, 0.0) * eps * 2
    return max(total, 0.0)


def post_ber_from_profile(prof: ResidualProfile, eps: float,
                          which: str = "info") -> float:
    r = prof.r_info if which == "info" else prof.r_word
    return mix_post_ber(prof.n_cells, r, eps)


# ---------------------------------------------------------------------------
# schemes
# ---------------------------------------------------------------------------

class NBLDPCScheme:
    """The paper's scheme: NB-LDPC over GF(p) + the vectorized FBP decoder.

    `channel` picks the fault physics: the default `PlusMinusOne` is the
    paper's ±1 integer-error channel (memory cells holding small integers /
    PIM MAC outputs); any level-domain `repro.memory.channel` model plugs in
    for MLC device studies. Residuals are measured over decoded values in
    the channel's own domain.
    """

    analytic = False

    def __init__(self, code: LDPCCode, channel: Channel | None = None, *,
                 n_iters: int = 12, damping: float = 0.3,
                 llv_scale: float = 4.0, llv_mode: str = "manhattan",
                 name: str | None = None):
        self.code = code
        self.channel = channel if channel is not None else PlusMinusOne(
            0.0, p_field=code.p)
        if self.channel.p != code.p:
            raise ValueError(f"channel alphabet {self.channel.p} != code "
                             f"field GF({code.p})")
        self.n_cells = code.n
        self.n_info = code.k
        self.name = name or f"nbldpc_n{code.n}_r{code.rate:.2f}"
        self._decode = jax.jit(lambda y: decode_integers(
            code, y, n_iters=n_iters, damping=damping, llv_scale=llv_scale,
            llv_mode=llv_mode, early_exit=True))

    def residuals_at(self, m: int, trials: int, seed: int):
        code = self.code
        key = jax.random.fold_in(jax.random.PRNGKey(seed), m)
        kw, kc = jax.random.split(key)
        w = jax.random.randint(kw, (trials, code.k), 0, code.p, jnp.int32)
        cw = encode_words(w, code)
        y = self.channel.corrupt_exact(kc, cw, m)
        y_corr, res = self._decode(y)
        # level-domain channels store field symbols, so the decoder's hard
        # symbol decisions are the read-back values; the integer channel
        # compares the arithmetic reinterpretation
        got = res.symbols if self.channel.domain == "level" else y_corr
        wrong = np.asarray(got != cw)
        return float(wrong.mean()), float(wrong[:, :code.k].mean())


class HammingSECDEDScheme:
    """Memory-mode bit-level baseline: Hamming(39,32)+parity per stored
    word (ASSCC'21-style). Raw BER is per stored *bit* (39 cells/word)."""

    analytic = False

    def __init__(self, n_data: int = 32, name: str = "hamming_secded"):
        self.impl = HammingSECDED(n_data)
        probe = self.impl.encode(np.zeros((1, n_data), np.int64))
        self.n_cells = probe.shape[-1]
        self.n_info = n_data
        self.name = name

    def residuals_at(self, m: int, trials: int, seed: int):
        rng = np.random.default_rng((seed << 8) ^ m)
        bits = rng.integers(0, 2, (trials, self.n_info))
        word = self.impl.encode(bits)
        for b in range(trials):
            idx = rng.choice(self.n_cells, m, replace=False)
            word[b, idx] ^= 1
        data, _unc = self.impl.decode(word)
        r_info = float((data != bits).mean())
        return r_info, r_info       # only data bits are observable downstream


class ModuloParityScheme:
    """Memory-mode modulo-checksum baseline (ESSCIRC'22-style): one mod-q
    checksum cell per k data cells. In memory mode the checksum cannot
    localize the failing cell without interrupting to re-read, so it is
    detect-only here: residuals equal the injected error fraction and the
    profile additionally records detection coverage per m."""

    analytic = False

    def __init__(self, k_data: int = 32, q: int = 3,
                 name: str = "modulo_parity"):
        self.impl = ModuloParity(q)
        self.n_cells = k_data + 1
        self.n_info = k_data
        self.q = q
        self.name = name

    def residuals_at(self, m: int, trials: int, seed: int):
        r = m / self.n_cells       # errors remain; info cells hit pro rata
        return r, r

    def detection_at(self, m: int, trials: int, seed: int) -> float:
        rng = np.random.default_rng((seed << 8) ^ m)
        W = rng.integers(0, self.q, (trials, self.n_info))
        Y = np.array(self.impl.encode_weights(jnp.asarray(W)))
        for b in range(trials):
            idx = rng.choice(self.n_cells, m, replace=False)
            Y[b, idx] += rng.choice([-1, 1], m)
        return float(np.asarray(self.impl.detect(jnp.asarray(Y))).mean())


class UnprotectedScheme:
    """Reference: no code — post-decode BER equals raw BER analytically."""

    analytic = True
    name = "unprotected"
    n_cells = 1
    n_info = 1

    def post_ber(self, eps: float, which: str = "info") -> float:
        return eps


# ---------------------------------------------------------------------------
# campaign driver
# ---------------------------------------------------------------------------

def default_max_errors(n_cells: int, eps_max: float) -> int:
    """Cover the binomial bulk at the largest requested raw BER: mean + 6 sd,
    clamped to [4, n_cells]."""
    mu = n_cells * eps_max
    m = math.ceil(mu + 6.0 * math.sqrt(max(mu, 1.0)))
    return int(np.clip(m, 4, n_cells))


def conditional_residual_profile(scheme, *, max_errors: int = 12,
                                 trials: int = 128,
                                 seed: int = 0) -> ResidualProfile:
    r_word = np.zeros(max_errors + 1)
    r_info = np.zeros(max_errors + 1)
    detected = None
    if hasattr(scheme, "detection_at"):
        detected = np.zeros(max_errors + 1)
    for m in range(1, max_errors + 1):
        r_word[m], r_info[m] = scheme.residuals_at(m, trials, seed)
        if detected is not None:
            detected[m] = scheme.detection_at(m, trials, seed)
    return ResidualProfile(scheme.name, scheme.n_cells, scheme.n_info,
                           r_word, r_info, detected)


def run_campaign(schemes: Sequence, raw_bers: Sequence[float], *,
                 max_errors=None, trials: int = 128, seed: int = 0,
                 hamming_trials: int = 2048) -> dict:
    """Run every scheme over every raw BER. Returns
    {"rows": [...], "profiles": {name: ResidualProfile}} where each row is
    {scheme, raw_ber, post_ber (info cells), post_ber_word, improvement}.

    `max_errors` may be None (auto per scheme from the largest raw BER), an
    int, or a {scheme_name: int} dict. Pure-numpy schemes (Hamming) get
    `hamming_trials` conditional trials — they are orders of magnitude
    cheaper than a decode run.
    """
    eps_max = max(raw_bers)
    rows: list[dict] = []
    profiles: dict[str, ResidualProfile] = {}
    for scheme in schemes:
        if scheme.analytic:
            for eps in raw_bers:
                rows.append({"scheme": scheme.name, "raw_ber": eps,
                             "post_ber": scheme.post_ber(eps),
                             "post_ber_word": scheme.post_ber(eps, "word"),
                             "improvement": 1.0})
            continue
        if isinstance(max_errors, dict):
            M = max_errors.get(scheme.name,
                               default_max_errors(scheme.n_cells, eps_max))
        elif max_errors is None:
            M = default_max_errors(scheme.n_cells, eps_max)
        else:
            M = int(max_errors)
        tr = (hamming_trials if isinstance(scheme, (HammingSECDEDScheme,
                                                    ModuloParityScheme))
              else trials)
        prof = conditional_residual_profile(scheme, max_errors=M, trials=tr,
                                            seed=seed)
        profiles[scheme.name] = prof
        # conditional-MC measurement floor: one residual cell across all
        # trials, pmf-weighted — improvements are reported against it
        floor = 1.0 / (tr * prof.n_cells)
        for eps in raw_bers:
            post = post_ber_from_profile(prof, eps, "info")
            rows.append({
                "scheme": scheme.name, "raw_ber": eps,
                "post_ber": post,
                "post_ber_word": post_ber_from_profile(prof, eps, "word"),
                "improvement": eps / max(post, floor * eps),
                "post_ber_floor": floor * eps,
            })
    return {"rows": rows, "profiles": profiles}


def paper_schemes(code: LDPCCode, *, n_iters: int = 12,
                  damping: float = 0.3) -> list:
    """The paper-style comparison set: NB-LDPC (this work) vs Hamming SECDED
    (memory-mode prior) vs modulo checksum (detect-only prior) vs
    unprotected, all under the ±1 cell-error channel."""
    return [
        NBLDPCScheme(code, PlusMinusOne(0.0, p_field=code.p),
                     n_iters=n_iters, damping=damping),
        HammingSECDEDScheme(),
        ModuloParityScheme(k_data=32, q=code.p),
        UnprotectedScheme(),
    ]


def select_acceptance_row(rows: Sequence[dict], *, nbldpc_prefix: str =
                          "nbldpc", hamming_name: str = "hamming_secded",
                          saturation: float = 3.0) -> dict | None:
    """The paper-style headline point: the largest raw BER at which Hamming
    SECDED has saturated (improvement <= `saturation`, i.e. double-bit
    errors dominate and the code has stopped helping) — report the NB-LDPC
    improvement there. Saturation is contiguous toward high raw BER, so the
    boundary (smallest saturated eps) is where the gap is widest. Returns
    None if Hamming never saturates on the grid."""
    ham = {r["raw_ber"]: r for r in rows if r["scheme"] == hamming_name}
    nb = {r["raw_ber"]: r for r in rows
          if r["scheme"].startswith(nbldpc_prefix)}
    saturated = sorted(e for e, r in ham.items()
                       if r["improvement"] <= saturation and e in nb)
    if not saturated:
        return None
    eps = saturated[0]
    return {
        "raw_ber": eps,
        "hamming_improvement": ham[eps]["improvement"],
        "hamming_post_ber": ham[eps]["post_ber"],
        "nbldpc_improvement": nb[eps]["improvement"],
        "nbldpc_post_ber": nb[eps]["post_ber"],
        "saturation_threshold": saturation,
    }
