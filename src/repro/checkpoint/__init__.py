"""Fault-tolerant checkpointing.

Design (DESIGN.md §5):
  - **atomic**: write to `<dir>/tmp.<step>/`, fsync, then `rename()` to
    `step_<N>/` — a crash mid-write never corrupts the latest checkpoint;
  - **sharded**: each leaf is saved as its own .npy inside an npz-like layout
    keyed by flattened pytree path — device-count independent;
  - **elastic**: restore takes target `shardings`; arrays are re-placed with
    `jax.device_put`, so a checkpoint written on mesh A restores onto mesh B
    (different pod count / data-parallel degree);
  - **self-describing**: `manifest.json` records step, data-pipeline state,
    mesh shape, and a payload checksum;
  - **NB-LDPC-protected payloads** (the paper's *memory mode*): optionally the
    serialized bytes of every array are GF(3)-symbolized, encoded with the
    framework's own code, and verified/corrected on load — the paper's ECC
    guarding the framework's own storage path (`protect=True`).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core import get_code, np_encode_words
from repro.core.decode import decode_integers
import jax.numpy as jnp


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _checksum(arrs: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrs):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrs[k]).tobytes())
    return h.hexdigest()[:16]


# -- NB-LDPC memory-mode protection of payload bytes ------------------------

_PROT_CODE = "wl1024_r08"


def _protect_bytes(raw: bytes) -> Dict[str, np.ndarray]:
    """bytes -> GF(3) symbols (4 per byte, base-3 digits of crumbs) encoded
    into codewords of the registry code. Returns dict of arrays to save."""
    code = get_code(_PROT_CODE)
    b = np.frombuffer(raw, np.uint8).astype(np.int64)
    crumbs = np.stack([(b >> (2 * i)) & 0x3 for i in range(4)], -1).reshape(-1)
    # 2-bit crumbs (0..3): symbolize as two GF(3) digits to stay in-field
    hi, lo = crumbs >> 1, crumbs & 1
    syms = np.stack([hi, lo], -1).reshape(-1)
    pad = (-syms.size) % code.k
    syms = np.pad(syms, (0, pad))
    words = syms.reshape(-1, code.k)
    enc = np_encode_words(words, code)
    return {"enc": enc.astype(np.int8), "nbytes": np.asarray([len(raw)])}


def _unprotect_bytes(enc: np.ndarray, nbytes: int, correct: bool = True) -> bytes:
    code = get_code(_PROT_CODE)
    enc = enc.astype(np.int64)
    if correct:
        # memory mode: stored values ARE field symbols, so take the decoder's
        # hard symbol decisions (not the arithmetic reinterpretation, which
        # maps to the nearest *integer* of the decoded residue class)
        _y, res = decode_integers(code, jnp.asarray(enc), n_iters=10,
                                  damping=0.3)
        enc = np.asarray(res.symbols)
    syms = enc[:, :code.k].reshape(-1)[:nbytes * 8]   # 2 digits x 4 crumbs/byte
    hi, lo = syms[0::2], syms[1::2]
    crumbs = ((np.clip(hi, 0, 1) << 1) | np.clip(lo, 0, 1)).reshape(-1, 4)
    b = sum(crumbs[:, i].astype(np.uint8) << (2 * i) for i in range(4))
    return b.astype(np.uint8).tobytes()


# -- public API --------------------------------------------------------------


def save_checkpoint(directory: str, step: int, tree, *, extra: Optional[dict]
                    = None, protect: bool = False, keep: int = 3) -> str:
    """Atomically persist `tree` (params/opt state/...) at `step`."""
    os.makedirs(directory, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)

    for k, arr in flat.items():
        fn = os.path.join(tmp, k.replace("/", "__") + ".npy")
        if protect:
            raw = arr.tobytes()
            prot = _protect_bytes(raw)
            np.savez(fn + ".prot.npz", dtype=str(arr.dtype),
                     shape=np.asarray(arr.shape), **prot)
        else:
            np.save(fn, arr)

    manifest = {
        "step": step,
        "time": time.time(),
        "checksum": _checksum(flat),
        "protected": protect,
        "extra": extra or {},
        "leaves": sorted(flat),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish

    # retention
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, old))
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, *, step: Optional[int] = None,
                       shardings=None, correct: bool = True):
    """Restore into `template`'s structure. `shardings`: optional pytree of
    Sharding (tree-prefix ok) for elastic re-placement onto the current mesh.
    Returns (tree, manifest)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat = {}
    for key in manifest["leaves"]:
        fn = os.path.join(d, key.replace("/", "__") + ".npy")
        if manifest["protected"]:
            z = np.load(fn + ".prot.npz")
            raw = _unprotect_bytes(z["enc"], int(z["nbytes"][0]), correct)
            arr = np.frombuffer(raw, dtype=np.dtype(str(z["dtype"])))
            flat[key] = arr.reshape(tuple(int(s) for s in z["shape"]))
        else:
            flat[key] = np.load(fn)

    if manifest["protected"] is False and _checksum(flat) != manifest["checksum"]:
        raise IOError(f"checkpoint {d} failed checksum verification")

    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest
