"""Fault-tolerant checkpointing.

Design (DESIGN.md §5):
  - **atomic**: write to `<dir>/tmp.<step>/`, fsync, then `rename()` to
    `step_<N>/` — a crash mid-write never corrupts the latest checkpoint;
  - **sharded**: each leaf is saved as its own .npy inside an npz-like layout
    keyed by flattened pytree path — device-count independent;
  - **elastic**: restore takes target `shardings`; arrays are re-placed with
    `jax.device_put`, so a checkpoint written on mesh A restores onto mesh B
    (different pod count / data-parallel degree);
  - **self-describing**: `manifest.json` records step, data-pipeline state,
    mesh shape, and a payload checksum;
  - **NB-LDPC-protected payloads** (the paper's *memory mode*): optionally the
    serialized bytes of every array are packed into GF(p) codewords through
    `repro.memory.ProtectedMemoryArray` (base-p symbolization + systematic
    encode) and verified/corrected on load — the paper's ECC guarding the
    framework's own storage path (`protect=True`). Storage faults are
    injected through the `repro.memory.channel` models via
    `inject_storage_faults`, never by hand-editing the `.prot.npz` files.
"""
from __future__ import annotations

import glob
import hashlib
import json
import os
import shutil
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.memory import Channel, ProtectedMemoryArray, StoredTensor


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _unflatten_into(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, _leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _checksum(arrs: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrs):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrs[k]).tobytes())
    return h.hexdigest()[:16]


# -- NB-LDPC memory-mode protection of payloads ------------------------------

_PROT_CODE = "wl1024_r08"
_PROT_VERSION = 2          # v2: base-p symbolization via repro.memory (v1
#                            was the pre-subsystem crumb encoding)


def _protected_memory() -> ProtectedMemoryArray:
    return ProtectedMemoryArray(_PROT_CODE, controller="basic", n_iters=10,
                                damping=0.3)


def _stored_to_npz(st: StoredTensor) -> dict[str, np.ndarray]:
    return {"enc": st.enc, "nbytes": np.asarray([st.nbytes]),
            "dtype": str(st.dtype), "shape": np.asarray(st.shape, np.int64)}


def _npz_to_stored(z) -> StoredTensor:
    return StoredTensor(np.asarray(z["enc"], np.int8), str(z["dtype"]),
                        tuple(int(s) for s in z["shape"]),
                        int(z["nbytes"][0]))


def inject_storage_faults(directory: str, channel: Channel, *,
                          key: int = 0, step: int | None = None,
                          t: float = 0.0, n_reads: int = 0) -> int:
    """Corrupt a protected checkpoint's stored codewords in place through a
    `repro.memory.channel` model (the supported way to simulate storage rot
    — callers never touch the `.prot.npz` layout). Returns cells changed."""
    if channel.domain != "level":
        raise ValueError(f"{type(channel).__name__} is an integer-domain "
                         "channel; stored cells need a level-domain one")
    from repro.core.codes import REGISTRY
    p = REGISTRY[_PROT_CODE][2]      # field size without building the code
    if channel.p != p:
        raise ValueError(f"channel alphabet {channel.p} != GF({p})")
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    base = jax.random.PRNGKey(key)
    changed = 0
    for i, fn in enumerate(sorted(glob.glob(os.path.join(d, "*.prot.npz")))):
        z = dict(np.load(fn, allow_pickle=False))
        enc = np.asarray(z["enc"], np.int8)
        new = np.asarray(channel.apply(jax.random.fold_in(base, i),
                                       jnp.asarray(enc, jnp.int32),
                                       t=t, n_reads=n_reads), np.int8)
        changed += int((new != enc).sum())
        z["enc"] = new
        with open(fn, "wb") as f:
            np.savez(f, **z)
    return changed


# -- public API --------------------------------------------------------------


def save_checkpoint(directory: str, step: int, tree, *, extra: dict | None
                    = None, protect: bool = False, keep: int = 3) -> str:
    """Atomically persist `tree` (params/opt state/...) at `step`."""
    os.makedirs(directory, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)

    mem = _protected_memory() if protect else None
    for k, arr in flat.items():
        fn = os.path.join(tmp, k.replace("/", "__") + ".npy")
        if protect:
            st = mem.write(k, arr)
            np.savez(fn + ".prot.npz", **_stored_to_npz(st))
            mem.discard(k)           # one leaf resident at a time
        else:
            np.save(fn, arr)

    manifest = {
        "step": step,
        "time": time.time(),
        "checksum": _checksum(flat),
        "protected": protect,
        "prot_version": _PROT_VERSION if protect else None,
        "prot_code": _PROT_CODE if protect else None,
        "extra": extra or {},
        "leaves": sorted(flat),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish

    # retention
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, old))
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, *, step: int | None = None,
                       shardings=None, correct: bool = True):
    """Restore into `template`'s structure. `shardings`: optional pytree of
    Sharding (tree-prefix ok) for elastic re-placement onto the current mesh.
    Returns (tree, manifest)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    mem = None
    if manifest["protected"]:
        if manifest.get("prot_version") != _PROT_VERSION:
            raise OSError(
                f"checkpoint {d} uses protected-payload format "
                f"{manifest.get('prot_version')}; this build reads "
                f"version {_PROT_VERSION}")
        mem = ProtectedMemoryArray(manifest.get("prot_code", _PROT_CODE),
                                   controller="basic", n_iters=10,
                                   damping=0.3)

    flat = {}
    for key in manifest["leaves"]:
        fn = os.path.join(d, key.replace("/", "__") + ".npy")
        if mem is not None:
            z = np.load(fn + ".prot.npz")
            mem.import_stored(key, _npz_to_stored(z))
            flat[key] = mem.read(key, correct=correct)
            mem.discard(key)         # one leaf resident at a time
        else:
            flat[key] = np.load(fn)

    if mem is not None:
        manifest["correction_stats"] = mem.stats.as_dict()
    if _checksum(flat) != manifest["checksum"]:
        if not manifest["protected"]:
            raise OSError(f"checkpoint {d} failed checksum verification")
        if correct:
            raise OSError(f"checkpoint {d} failed post-correction checksum "
                          "(storage errors exceeded the code's strength)")

    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest
