"""Core NB-LDPC arithmetic error correction for PIM (the paper's contribution)."""
from .construction import LDPCCode, build_code
from .codes import get_code, REGISTRY as CODE_REGISTRY
from .encode import (encode_words, encode_weight_matrix, syndrome,
                     np_encode_words)
from .decode import (decode_llv, decode_integers, DecodeResult, maxplus_conv,
                     maxplus_conv_ref)
from .llv import init_llv, reinterpret, circular_distance
from .pim import PIMConfig, pim_mac
from .protected import (ProtectionConfig, ProtectedResult,
                        protected_pim_matmul, prepare_weights, strip_padding,
                        decode_stream, decode_pipelined)
from .context import PIMContext
