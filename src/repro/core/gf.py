"""GF(p) arithmetic and linear algebra over prime fields.

The paper's NB-LDPC code lives in GF(p) with p prime (prototype: GF(3)).
Construction-time linear algebra (systematic generator derivation, rank checks)
runs in numpy; runtime arithmetic (encode / syndrome / decoder index
permutations) has jnp equivalents used inside jitted code.
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

__all__ = [
    "is_prime", "gf_add", "gf_sub", "gf_mul", "gf_inv", "gf_neg",
    "mul_table", "inv_table", "perm_table",
    "gf_matmul_np", "gf_rref", "gf_mat_inv", "gf_rank",
    "centered_lift", "to_field",
]


def is_prime(p: int) -> bool:
    if p < 2:
        return False
    return all(p % d for d in range(2, int(p ** 0.5) + 1))


# ---------------------------------------------------------------------------
# scalar / array ops (work for numpy and jax arrays alike)
# ---------------------------------------------------------------------------

def gf_add(a, b, p: int):
    return (a + b) % p


def gf_sub(a, b, p: int):
    return (a - b) % p


def gf_mul(a, b, p: int):
    return (a * b) % p


def gf_neg(a, p: int):
    return (-a) % p


@functools.lru_cache(maxsize=None)
def _inv_list(p: int) -> tuple:
    """Multiplicative inverses; index 0 unused (set to 0)."""
    assert is_prime(p), f"GF(p) requires prime p, got {p}"
    return tuple([0] + [pow(a, p - 2, p) for a in range(1, p)])


def gf_inv(a: int, p: int) -> int:
    a = int(a) % p
    if a == 0:
        raise ZeroDivisionError("0 has no inverse in GF(p)")
    return _inv_list(p)[a]


@functools.lru_cache(maxsize=None)
def mul_table(p: int) -> np.ndarray:
    """(p, p) multiplication table."""
    k = np.arange(p)
    return (k[:, None] * k[None, :]) % p


@functools.lru_cache(maxsize=None)
def inv_table(p: int) -> np.ndarray:
    return np.asarray(_inv_list(p), dtype=np.int32)


@functools.lru_cache(maxsize=None)
def perm_table(p: int) -> np.ndarray:
    """perm_table(p)[h, k] = (h * k) % p.

    Used to permute LLV vectors along the GF axis when messages travel an edge
    with coefficient h (paper Eq. 6): msg_out[(h*k) % p] = msg_in[k], i.e.
    msg_out[k] = msg_in[(h^{-1} * k) % p].
    """
    return mul_table(p).astype(np.int32)


# ---------------------------------------------------------------------------
# numpy linear algebra mod p (construction time)
# ---------------------------------------------------------------------------

def gf_matmul_np(a: np.ndarray, b: np.ndarray, p: int) -> np.ndarray:
    return (a.astype(np.int64) @ b.astype(np.int64)) % p


def gf_rref(mat: np.ndarray, p: int):
    """Reduced row-echelon form of `mat` over GF(p).

    Returns (rref, pivot_cols). Row operations only; column order preserved.
    """
    m = mat.astype(np.int64) % p
    rows, cols = m.shape
    pivots = []
    r = 0
    for c in range(cols):
        if r >= rows:
            break
        nz = np.nonzero(m[r:, c])[0]
        if nz.size == 0:
            continue
        pr = r + nz[0]
        if pr != r:
            m[[r, pr]] = m[[pr, r]]
        m[r] = (m[r] * gf_inv(int(m[r, c]), p)) % p
        for rr in range(rows):
            if rr != r and m[rr, c] != 0:
                m[rr] = (m[rr] - m[rr, c] * m[r]) % p
        pivots.append(c)
        r += 1
    return m % p, pivots


def gf_rank(mat: np.ndarray, p: int) -> int:
    _, piv = gf_rref(mat, p)
    return len(piv)


def gf_mat_inv(mat: np.ndarray, p: int) -> np.ndarray:
    """Inverse of a square matrix over GF(p)."""
    n = mat.shape[0]
    aug = np.concatenate([mat % p, np.eye(n, dtype=np.int64)], axis=1)
    rref, piv = gf_rref(aug, p)
    if piv[:n] != list(range(n)):
        raise np.linalg.LinAlgError("matrix is singular over GF(p)")
    return rref[:, n:] % p


# ---------------------------------------------------------------------------
# integer <-> field helpers (the "arithmetic" part of the arithmetic code)
# ---------------------------------------------------------------------------

def to_field(x, p: int):
    """Map integers (possibly negative, e.g. differential weights) to GF(p)."""
    return x % p


def centered_lift(k, p: int):
    """Lift field element k in [0, p) to the centered representative in
    (-p/2, p/2].  For p=3: {0:0, 1:1, 2:-1} — the differential ternary map."""
    k = k % p
    if isinstance(k, (np.ndarray,)):
        return np.where(k > p // 2, k - p, k)
    return jnp.where(k > p // 2, k - p, k)
