"""Encoding: systematic NB-LDPC encode of words and of weight matrices.

Memory mode  (paper §3.1): w' = w · H_G, i.e. checks r = w · P  (mod p).
PIM mode     (paper Eq. 4): every *row* of the stored weight matrix is a
codeword; the MAC output then satisfies Y' · H_Cᵀ ≡ 0 (mod p) by linearity.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .construction import LDPCCode


def encode_words(w, code: LDPCCode):
    """w: (..., k) field symbols -> (..., n) codewords [w | checks]."""
    P = jnp.asarray(code.P, dtype=jnp.int32)
    checks = (w.astype(jnp.int32) @ P) % code.p
    return jnp.concatenate([w.astype(jnp.int32), checks], axis=-1)


def syndrome(y_field, code: LDPCCode):
    """y_field: (..., n) field symbols -> (..., c) syndromes (mod p)."""
    H = jnp.asarray(code.H, dtype=jnp.int32)
    return (y_field.astype(jnp.int32) @ H.T) % code.p


def encode_weight_matrix(W_int, code: LDPCCode):
    """Encode integer weights for PIM storage.

    W_int: (n_in, n_blocks * k) integers (e.g. differential ternary in
    {-1,0,1}).  Returns W_enc (n_in, n_blocks * n) where each k-column block
    gains c check columns computed over GF(p), stored as *centered* integers so
    ternary hardware cells can hold them (for p=3 checks land in {-1,0,1}).
    """
    n_in, n_out = W_int.shape
    assert n_out % code.k == 0, f"out dim {n_out} not a multiple of k={code.k}"
    nb = n_out // code.k
    Wb = W_int.reshape(n_in, nb, code.k)
    P = jnp.asarray(code.P, dtype=jnp.int32)
    checks = (Wb.astype(jnp.int32) % code.p) @ P % code.p
    # centered lift keeps check cells in the same dynamic range as data cells
    checks = jnp.where(checks > code.p // 2, checks - code.p, checks)
    W_enc = jnp.concatenate([Wb.astype(jnp.int32), checks], axis=-1)
    return W_enc.reshape(n_in, nb * code.n)


def np_encode_words(w: np.ndarray, code: LDPCCode) -> np.ndarray:
    """Host-side systematic encode (checkpoint / ProtectedMemoryArray write
    path). Symbols and P entries live in [0, p), so when every accumulated
    product is bounded by k*(p-1)^2 << 2^24 the matmul runs in float32 to
    hit BLAS — NumPy integer matmul is a slow C loop."""
    wmax = int(np.abs(w).max()) if w.size else 0
    if code.k * wmax * (code.p - 1) < 2 ** 24:
        prods = w.astype(np.float32) @ code.P.astype(np.float32)
        checks = prods.astype(np.int64) % code.p
    else:
        checks = (w.astype(np.int64) @ code.P) % code.p
    return np.concatenate([w.astype(np.int64), checks], axis=-1)
