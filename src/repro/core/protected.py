"""Protected PIM matmul — the paper's technique as a composable JAX op.

Pipeline (paper Fig. 2(a), §3):
  1. weight columns are partitioned into codeword blocks; check columns are
     generated over GF(p) and stored alongside (encode_weight_matrix),
  2. the PIM MAC computes over data+check columns in one pass (Eq. 4) —
     the dataflow is never interrupted,
  3. syndrome check on the integer MAC output (Eq. 5) detects errors,
  4. the NB-LDPC decoder corrects the residues and the corrected integers are
     re-interpreted (nearest representative, §3.2.3),
  5. check columns are dropped.

Everything is shard-local when codeword blocks align with the tensor-parallel
shard width (see DESIGN.md §3), so this op introduces no collectives.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .construction import LDPCCode
from .decode import DecodeResult, decode_integers
from .encode import encode_weight_matrix, syndrome
from .pim import PIMConfig, pim_mac


@dataclasses.dataclass(frozen=True)
class ProtectionConfig:
    code_name: str = "wl320_r08"
    mode: str = "correct"            # "off" | "detect" | "correct"
    n_iters: int = 8
    llv_scale: float = 4.0
    llv_mode: str = "manhattan"
    early_exit: bool = False         # lax.while_loop early termination
    damping: float = 0.3             # message damping (beyond-paper stabilizer)


class ProtectedResult(NamedTuple):
    y: jnp.ndarray                   # (B, n_out) corrected integer MAC results
    detected: jnp.ndarray            # (B, n_blocks) any-error-detected flags
    uncorrected: jnp.ndarray         # (B, n_blocks) decoder gave up (detect_fail)


def protected_pim_matmul(x: jnp.ndarray, W_enc: jnp.ndarray, code: LDPCCode,
                         prot: ProtectionConfig, pim_cfg: PIMConfig,
                         key: jax.Array | None = None,
                         cn_fbp=None) -> ProtectedResult:
    """x: (B, n_in) ints; W_enc: (n_in, nb * code.n) encoded weights."""
    B = x.shape[0]
    assert W_enc.shape[1] % code.n == 0
    nb = W_enc.shape[1] // code.n

    y = pim_mac(x, W_enc, pim_cfg, key=key)                  # (B, nb*n) noisy MAC
    yb = y.reshape(B * nb, code.n)

    if prot.mode == "off":
        data = yb[:, :code.k].reshape(B, nb * code.k)
        z = jnp.zeros((B, nb), bool)
        return ProtectedResult(data, z, z)

    s = syndrome(yb % code.p, code)                          # (B*nb, c)
    detected = (s != 0).any(axis=-1).reshape(B, nb)

    if prot.mode == "detect":
        data = yb[:, :code.k].reshape(B, nb * code.k)
        return ProtectedResult(data, detected, detected)

    y_corr, res = decode_integers(
        code, yb, n_iters=prot.n_iters, llv_scale=prot.llv_scale,
        llv_mode=prot.llv_mode, early_exit=prot.early_exit,
        damping=prot.damping, cn_fbp=cn_fbp)
    data = y_corr[:, :code.k].reshape(B, nb * code.k)
    return ProtectedResult(data, detected, res.detect_fail.reshape(B, nb))


def prepare_weights(W_int: jnp.ndarray, code: LDPCCode) -> jnp.ndarray:
    """Pad the output dim to a codeword multiple and encode. Returns W_enc;
    callers must remember original width to strip padding after the matmul."""
    n_in, n_out = W_int.shape
    pad = (-n_out) % code.k
    if pad:
        W_int = jnp.pad(W_int, ((0, 0), (0, pad)))
    return encode_weight_matrix(W_int, code)


def strip_padding(y: jnp.ndarray, n_out: int) -> jnp.ndarray:
    return y[..., :n_out]


def protected_pim_matmul_budgeted(x: jnp.ndarray, W_enc: jnp.ndarray,
                                  code: LDPCCode, prot: ProtectionConfig,
                                  pim_cfg: PIMConfig,
                                  key: jax.Array | None = None,
                                  budget: int = 16,
                                  cn_fbp=None) -> ProtectedResult:
    """Detect-then-correct with a fixed decode budget (serving fast path).

    The syndrome check rides along for free (the paper's no-interruption
    property); the iterative FBP decoder — the expensive part — runs only on
    up to `budget` flagged words per call, gathered into a dense mini-batch
    and scattered back. At raw BER ~1e-5 the expected flagged fraction is
    <<1%, so the amortized correction cost is ~budget/n_words of the
    always-on decoder while correcting everything the full path would
    (overflow beyond the budget is reported in `uncorrected`).
    """
    B = x.shape[0]
    assert W_enc.shape[1] % code.n == 0
    nb = W_enc.shape[1] // code.n

    y = pim_mac(x, W_enc, pim_cfg, key=key)
    yb = y.reshape(B * nb, code.n)
    s = syndrome(yb % code.p, code)
    flagged = (s != 0).any(axis=-1)                      # (B*nb,)
    detected = flagged.reshape(B, nb)

    # gather up to `budget` flagged words (priority: any flagged first)
    k = min(budget, B * nb)
    score = flagged.astype(jnp.float32)
    _, idx = jax.lax.top_k(score, k)                     # flagged word indices
    sel = yb[idx]                                        # (k, n)
    sel_corr, res = decode_integers(
        code, sel, n_iters=prot.n_iters, llv_scale=prot.llv_scale,
        llv_mode=prot.llv_mode, damping=prot.damping, cn_fbp=cn_fbp)
    # only write back genuinely-flagged rows (top_k pads with unflagged)
    take = flagged[idx]
    yb = yb.at[idx].set(jnp.where(take[:, None], sel_corr, yb[idx]))

    # a word stays uncorrected when the decoder gave up on it (per-word
    # detect_fail scattered back to its slot) or when the budget never
    # reached it (flagged but unselected); corrected words are NOT blamed
    # for an overflow elsewhere in the batch.
    word_fail = jnp.zeros(B * nb, bool).at[idx].set(res.detect_fail & take)
    selected = jnp.zeros(B * nb, bool).at[idx].set(take)
    uncorrected = (word_fail | (flagged & ~selected)).reshape(B, nb)
    data = yb.reshape(B, nb, code.n)[..., :code.k].reshape(B, nb * code.k)
    return ProtectedResult(data, detected, uncorrected)


def _chunk_runner(code: LDPCCode, *, n_iters: int, llv_scale: float,
                  llv_mode: str, early_exit: bool, damping: float, cn_fbp,
                  mesh, chunk_size: int):
    """One jitted fixed-shape (chunk_size, n) decode executable, shard_map'd
    over `mesh` when given. Shared by `decode_stream` / `decode_pipelined`
    so both stream through identical cached executables."""
    if mesh is not None:
        mesh_size = int(np_prod_mesh(mesh))
        if chunk_size % mesh_size != 0:
            raise ValueError(
                f"chunk_size={chunk_size} is not a multiple of the mesh "
                f"size {mesh_size}; every padded chunk is shard_map'd over "
                "the mesh, so pick a chunk_size divisible by the device "
                "count")
        from repro.distributed.sharding import decode_sharded

        def run(yy):
            return decode_sharded(code, yy, mesh=mesh, n_iters=n_iters,
                                  llv_scale=llv_scale, llv_mode=llv_mode,
                                  early_exit=early_exit, damping=damping,
                                  cn_fbp=cn_fbp)
    else:
        def run(yy):
            return decode_integers(code, yy, n_iters=n_iters,
                                   llv_scale=llv_scale, llv_mode=llv_mode,
                                   early_exit=early_exit, damping=damping,
                                   cn_fbp=cn_fbp)

    return jax.jit(run)


def np_prod_mesh(mesh) -> int:
    """Total device count of a `jax.sharding.Mesh` (its shape values)."""
    size = 1
    for v in mesh.shape.values():
        size *= int(v)
    return size


def _pad_chunk(y, chunk_size: int):
    """Right-pad a (b, n) chunk with all-zero words (valid codewords) to the
    executable's fixed row count. Returns (padded, true b)."""
    b = y.shape[0]
    if b > chunk_size:
        raise ValueError(f"chunk of {b} words exceeds chunk_size="
                         f"{chunk_size}")
    if b < chunk_size:
        y = jnp.concatenate(
            [y, jnp.zeros((chunk_size - b, y.shape[1]), y.dtype)], axis=0)
    return y, b


def decode_stream(code: LDPCCode, stream, *, chunk_size: int = 256,
                  n_iters: int = 8, llv_scale: float = 4.0,
                  llv_mode: str = "manhattan", early_exit: bool = True,
                  damping: float = 0.0, cn_fbp=None, mesh=None):
    """Streaming chunked decode for workloads larger than one dispatch.

    `stream` is either a single (B, n) integer array — chunked internally
    into `chunk_size`-word slices — or any iterable of (b_i, n) arrays
    (b_i <= chunk_size). Yields one `(y_corrected, DecodeResult)` pair per
    chunk, in order.

    Every chunk is right-padded with all-zero words (valid codewords) to
    exactly `chunk_size` before dispatch, so a SINGLE jitted executable
    serves the whole stream — no per-chunk recompilation, including the
    ragged tail. Results are sliced back to each chunk's true length.

    With `mesh` set (a `jax.sharding.Mesh` with a "data" axis), each padded
    chunk is additionally shard_map'd across the mesh devices via
    `repro.distributed.sharding.decode_sharded`; `chunk_size` must then be a
    multiple of the mesh size (validated up front, at the CALL — not on
    first consumption, and not as an opaque shard_map shape error).
    """
    if hasattr(stream, "shape"):
        arr = stream
        stream = (arr[i:i + chunk_size]
                  for i in range(0, arr.shape[0], chunk_size))

    run = _chunk_runner(code, n_iters=n_iters, llv_scale=llv_scale,
                        llv_mode=llv_mode, early_exit=early_exit,
                        damping=damping, cn_fbp=cn_fbp, mesh=mesh,
                        chunk_size=chunk_size)

    def gen():
        for y in stream:
            y2, b = _pad_chunk(y, chunk_size)
            y_corr, res = run(y2)
            yield y_corr[:b], DecodeResult(res.symbols[:b],
                                           res.llv_totals[:b],
                                           res.detect_fail[:b],
                                           res.iterations[:b])
    return gen()


def decode_pipelined(code: LDPCCode, pages, *, chunk_size: int = 256,
                     n_iters: int = 8, llv_scale: float = 4.0,
                     llv_mode: str = "manhattan", early_exit: bool = True,
                     damping: float = 0.0, cn_fbp=None, mesh=None,
                     depth: int = 1):
    """Double-buffered paged decode: the corrected-read pipeline behind
    `repro.memory.paged.PagedProtectedStore` serving reads.

    Same contract as `decode_stream` (iterable of (b_i, n) pages, one
    `(y_corrected, DecodeResult)` per page, single cached executable), but
    page i+1's decode is DISPATCHED before page i's result is yielded:
    jax dispatch is asynchronous, so while the consumer (attention, a scrub
    writer, ...) processes page i on its own stream, the decoder is already
    chewing on page i+1 — decode latency hides behind consumption instead
    of serializing with it. `depth` pages are kept in flight (1 = classic
    double buffering).
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    if hasattr(pages, "shape"):
        arr = pages
        pages = (arr[i:i + chunk_size]
                 for i in range(0, arr.shape[0], chunk_size))

    run = _chunk_runner(code, n_iters=n_iters, llv_scale=llv_scale,
                        llv_mode=llv_mode, early_exit=early_exit,
                        damping=damping, cn_fbp=cn_fbp, mesh=mesh,
                        chunk_size=chunk_size)

    def dispatch(y):
        y, b = _pad_chunk(y, chunk_size)
        y_corr, res = run(y)          # async: returns immediately
        return y_corr, res, b

    def gen():
        inflight = collections.deque()
        for y in pages:
            inflight.append(dispatch(y))
            if len(inflight) > depth:
                y_corr, res, b = inflight.popleft()
                yield y_corr[:b], DecodeResult(
                    res.symbols[:b], res.llv_totals[:b], res.detect_fail[:b],
                    res.iterations[:b])
        while inflight:
            y_corr, res, b = inflight.popleft()
            yield y_corr[:b], DecodeResult(
                res.symbols[:b], res.llv_totals[:b], res.detect_fail[:b],
                res.iterations[:b])
    return gen()
