"""NB-LDPC code construction.

Progressive Edge Growth (PEG) construction of a sparse check matrix H_C over
GF(p) (paper cites PEG [26] / PCEG [11]), followed by derivation of a systematic
generator G = [I | P] with G · H_Cᵀ = 0 (paper Eq. 2).

The returned `LDPCCode` carries both the dense matrices (encode / syndrome) and
padded edge arrays + GF-permutation gather tables consumed by the vectorized
decoder (`repro.core.decode`) and the Pallas kernels (`repro.kernels`).
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque

import numpy as np

from . import gf

__all__ = ["LDPCCode", "peg_construct", "build_code"]


@dataclasses.dataclass(frozen=True)
class LDPCCode:
    """A systematic NB-LDPC code over GF(p).

    Layout: codeword = [k info symbols | n-k check symbols].
    """
    p: int
    n: int                     # codeword length (symbols); paper's word length l
    k: int                     # info symbols; paper's m
    H: np.ndarray              # (c, n) check matrix, c = n - k (systematic col order)
    G: np.ndarray              # (k, n) generator [I_k | P]
    P: np.ndarray              # (k, c) check-symbol generator
    # CN-centric padded edge arrays (decoder):
    cn_vns: np.ndarray         # (c, dc_max) int32 vn index, -1 padding
    cn_coefs: np.ndarray       # (c, dc_max) int32 edge coefficient, 1 padding
    cn_mask: np.ndarray        # (c, dc_max) bool, True = real edge
    perm_to_contrib: np.ndarray  # (c, dc_max, p) int32 gather idx: msg_hat[k]=msg[idx[...,k]]
    perm_to_sym: np.ndarray      # (c, dc_max, p) int32 gather idx back to symbol space
    dv: int                    # nominal VN degree
    dc_max: int

    @property
    def c(self) -> int:
        return self.n - self.k

    @property
    def rate(self) -> float:
        return self.k / self.n

    @property
    def n_edges(self) -> int:
        return int(self.cn_mask.sum())


def peg_construct(n: int, c: int, dv: int, p: int, seed: int = 0) -> np.ndarray:
    """Progressive Edge Growth: build a (c, n) sparse parity matrix over GF(p).

    For each VN (in order) place `dv` edges; each edge goes to the check node
    that is farthest from the VN in the current Tanner graph (maximizing local
    girth), breaking ties by lowest CN degree then randomly.
    """
    rng = np.random.default_rng(seed)
    # adjacency: vn -> set of cns, cn -> set of vns
    vn_adj = [[] for _ in range(n)]
    cn_adj = [[] for _ in range(c)]
    cn_deg = np.zeros(c, dtype=np.int64)

    def bfs_cn_distances(root_vn: int) -> np.ndarray:
        """Distance (in edges/2) from root VN to every CN; -1 = unreachable."""
        dist = np.full(c, -1, dtype=np.int64)
        seen_vn = np.zeros(n, dtype=bool)
        seen_vn[root_vn] = True
        frontier = deque([root_vn])
        depth = 0
        while frontier:
            depth += 1
            nxt = deque()
            for v in frontier:
                for cc in vn_adj[v]:
                    if dist[cc] == -1:
                        dist[cc] = depth
                        for v2 in cn_adj[cc]:
                            if not seen_vn[v2]:
                                seen_vn[v2] = True
                                nxt.append(v2)
            frontier = nxt
        return dist

    H = np.zeros((c, n), dtype=np.int64)
    nonzero = np.arange(1, p)
    for v in range(n):
        for e in range(dv):
            if e == 0 and not vn_adj[v]:
                cand = np.flatnonzero(cn_deg == cn_deg.min())
            else:
                dist = bfs_cn_distances(v)
                unreachable = np.flatnonzero(dist == -1)
                if unreachable.size:
                    cand = unreachable
                else:
                    far = dist.max()
                    cand = np.flatnonzero(dist == far)
                # exclude CNs already connected to v (parallel edges illegal)
                cand = np.array([cc for cc in cand if cc not in vn_adj[v]],
                                dtype=np.int64)
                if cand.size == 0:   # fully connected corner case
                    cand = np.array([cc for cc in range(c) if cc not in vn_adj[v]],
                                    dtype=np.int64)
            mindeg = cn_deg[cand].min()
            cand = cand[cn_deg[cand] == mindeg]
            cc = int(rng.choice(cand))
            vn_adj[v].append(cc)
            cn_adj[cc].append(v)
            cn_deg[cc] += 1
            H[cc, v] = int(rng.choice(nonzero))
    return H


def _systematize(H: np.ndarray, p: int, rng: np.random.Generator):
    """Column-permute H so its last c columns are invertible; return
    (H_sys, perm) with H_sys = H[:, perm]."""
    c, n = H.shape
    rref, piv = gf.gf_rref(H, p)
    if len(piv) < c:
        raise np.linalg.LinAlgError("H is rank deficient")
    piv = list(piv)
    info = [j for j in range(n) if j not in set(piv)]
    perm = np.array(info + piv, dtype=np.int64)
    return H[:, perm] % p, perm


@functools.lru_cache(maxsize=64)
def build_code(n: int, k: int, p: int = 3, dv: int = 3, seed: int = 0) -> LDPCCode:
    """Construct a systematic NB-LDPC code: PEG graph + random GF coefficients.

    Retries with fresh coefficient draws if H comes out rank-deficient.
    """
    assert gf.is_prime(p), f"p must be prime, got {p}"
    assert 0 < k < n
    c = n - k
    rng = np.random.default_rng(seed ^ 0x5EED)
    H = None
    for attempt in range(8):
        Hc = peg_construct(n, c, dv, p, seed=seed + 1000 * attempt)
        if gf.gf_rank(Hc, p) == c:
            H = Hc
            break
    if H is None:
        raise RuntimeError(f"PEG failed to produce full-rank H for n={n},k={k},p={p}")

    H_sys, _ = _systematize(H, p, rng)
    A, B = H_sys[:, :k], H_sys[:, k:]
    Binv = gf.gf_mat_inv(B, p)
    # H [w | r]^T = 0  =>  r = -B^{-1} A w
    P = ((-(gf.gf_matmul_np(Binv, A, p)) % p).T) % p      # (k, c)
    G = np.concatenate([np.eye(k, dtype=np.int64), P], axis=1) % p
    assert not (gf.gf_matmul_np(G, H_sys.T, p)).any(), "G.H^T != 0"

    # ---- CN-centric edge arrays -------------------------------------------
    dc_all = (H_sys != 0).sum(axis=1)
    dc_max = int(dc_all.max())
    cn_vns = np.full((c, dc_max), -1, dtype=np.int32)
    cn_coefs = np.ones((c, dc_max), dtype=np.int32)
    cn_mask = np.zeros((c, dc_max), dtype=bool)
    for i in range(c):
        vns = np.flatnonzero(H_sys[i])
        cn_vns[i, :vns.size] = vns
        cn_coefs[i, :vns.size] = H_sys[i, vns]
        cn_mask[i, :vns.size] = True

    # GF-axis permutation gather tables (paper Eq. 6).
    # to contribution space: msg_hat[j] = msg[(h^{-1} j) % p]
    # back to symbol space:  msg[k]     = L''[(h k) % p]
    invs = gf.inv_table(p)
    ks = np.arange(p, dtype=np.int64)
    hinv = invs[cn_coefs % p].astype(np.int64)            # (c, dc_max)
    perm_to_contrib = ((hinv[..., None] * ks) % p).astype(np.int32)
    perm_to_sym = ((cn_coefs[..., None].astype(np.int64) * ks) % p).astype(np.int32)

    return LDPCCode(
        p=p, n=n, k=k, H=H_sys % p, G=G, P=P,
        cn_vns=cn_vns, cn_coefs=cn_coefs, cn_mask=cn_mask,
        perm_to_contrib=perm_to_contrib, perm_to_sym=perm_to_sym,
        dv=dv, dc_max=dc_max,
    )
