"""Vectorized NB-LDPC max-log decoder (paper §3.2).

Flooding-schedule message passing over the Tanner graph of H_C:
  VN i --(coef h)--> CN j carries an LLV vector over GF(p);
  CNs run Forward-Backward Propagation (FBP): cyclic *max-plus* convolutions
  over the group (GF(p), +)  (paper Eq. 7);
  VNs accumulate prior + extrinsic messages and take argmax (paper §3.2.3).

All state is batched: `B` codewords decode simultaneously; shapes are
  prior   (B, n, p)
  msgs_cv (B, c, dc, p)   CN->VN messages in each VN's symbol space
The heavy CN inner loop can be dispatched to the Pallas `fbp` kernel
(`repro.kernels.ops.fbp_cn`) or run as pure jnp (the reference path).

Engine notes (high-throughput path):

* `maxplus_conv` is a single gather / broadcast-add / reduce-max over a
  precomputed (p, p) cyclic index table — no Python-level p² unrolling.
  The original reference implementation is kept as `maxplus_conv_ref`
  (property-tested against the vectorized one, and used as the "seed"
  baseline by `benchmarks/bench_decoder_throughput.py`).
* VN totals are computed by a *gather* over a precomputed VN->edge table
  instead of a scatter-add, which is markedly faster on CPU/TPU backends.
* The middle extrinsic outputs of FBP are computed by ONE batched
  convolution over all interior slots instead of a per-slot Python loop.

Early-exit semantics (converged mask):

With `early_exit=True`, `decode_llv` runs a `lax.while_loop` carrying a
per-codeword boolean `done` mask (syndrome == 0). Codewords whose mask is
set are *frozen*: their messages and LLV totals stop updating, so their
outputs are bit-identical to what they were at their own convergence
iteration, regardless of how long stragglers keep the loop alive. The loop
terminates when every codeword has converged or `n_iters` is reached.
`DecodeResult.iterations` is therefore a per-codeword `(B,)` vector: entry
`b` is the number of message-passing iterations codeword `b` actually
consumed (its convergence iteration, or `n_iters` if it never converged).
The fixed-iteration path returns a `(B,)` vector filled with `n_iters` so
downstream consumers see one shape either way.
"""
from __future__ import annotations

import functools
from collections.abc import Callable
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitizer import (check_finite, check_gf_symbols,
                                      sanitizer_enabled)
from .construction import LDPCCode
from .llv import NEG_INF, init_llv, normalize_llv, reinterpret

__all__ = ["DecodeResult", "decode_llv", "decode_integers", "maxplus_conv",
           "maxplus_conv_ref"]


class DecodeResult(NamedTuple):
    symbols: jnp.ndarray        # (B, n) hard decisions in GF(p)
    llv_totals: jnp.ndarray     # (B, n, p) final accumulated LLVs
    detect_fail: jnp.ndarray    # (B,) True if final syndrome still nonzero
    iterations: jnp.ndarray     # (B,) iterations consumed per codeword


@functools.lru_cache(maxsize=32)
def _conv_index_table(p: int) -> np.ndarray:
    """idx[k, j] = (k - j) % p — gather table for cyclic max-plus conv."""
    ks = np.arange(p)[:, None]
    js = np.arange(p)[None, :]
    return ((ks - js) % p).astype(np.int32)


def maxplus_conv(a, b, p: int):
    """Cyclic max-plus convolution along the last (GF) axis — paper Eq. 7:
    out[k] = max_j a[(k - j) % p] + b[j].

    Vectorized: one gather of `a` through the (p, p) cyclic index table,
    one broadcast add against `b`, one reduce-max. No Python p² loop.
    """
    idx = jnp.asarray(_conv_index_table(p))            # (p, p)
    terms = a[..., idx] + b[..., None, :]              # (..., p, p)
    return terms.max(axis=-1)


def maxplus_conv_ref(a, b, p: int):
    """Original Python-unrolled reference (seed implementation). Kept as the
    semantic oracle for `maxplus_conv` and as the benchmark baseline."""
    outs = []
    for k in range(p):
        terms = [a[..., (k - j) % p] + b[..., j] for j in range(p)]
        outs.append(functools.reduce(jnp.maximum, terms))
    return jnp.stack(outs, axis=-1)


def _identity_msg(shape, p: int, dtype=jnp.float32):
    e = jnp.full(shape + (p,), NEG_INF, dtype=dtype)
    return e.at[..., 0].set(0.0)


def _reflect(ext, p: int):
    """out[..., k] = ext[..., (-k) % p] (reflection to the reverse element)."""
    refl_idx = (-jnp.arange(p)) % p
    return ext[..., refl_idx]


def _fbp_chains(m_hat, p: int, conv: Callable):
    """Forward/backward max-plus chains over the slot axis.

    m_hat: (..., dc, p). Returns (fm, bm) lists of (..., p) tensors with
    fm[t] = conv of slots 0..t and bm[t] = conv of slots t..dc-1. The chain
    is inherently serial over dc (it IS the algorithm, paper Fig. 3(c));
    each link is one vectorized convolution over the whole batch.
    """
    dc = m_hat.shape[-2]
    fm = [m_hat[..., 0, :]]
    for t in range(1, dc):
        fm.append(conv(fm[-1], m_hat[..., t, :], p))
    bm_rev = [m_hat[..., dc - 1, :]]
    for t in range(dc - 2, -1, -1):
        bm_rev.append(conv(m_hat[..., t, :], bm_rev[-1], p))
    return fm, bm_rev[::-1]


def _cn_fbp_make(conv: Callable):
    """Build a CN-FBP callable from a max-plus convolution primitive."""

    def cn_fbp(m_hat, p: int):
        """FBP over the slot axis.

        m_hat: (B, c, dc, p) messages in *contribution* space (padded slots
        must already hold the max-plus identity). Returns extrinsic L'' per
        slot, still in contribution space but already reflected (k -> -k),
        shape (B, c, dc, p).
        """
        dc = m_hat.shape[-2]
        fm, bm = _fbp_chains(m_hat, p, conv)
        if dc == 1:
            ext = _identity_msg(m_hat.shape[:-2], p, m_hat.dtype)[..., None, :]
            return _reflect(ext, p)
        # interior slots t=1..dc-2 all at once: conv(fm[t-1], bm[t+1]) with
        # the slot index folded into the batch — one conv instead of dc-2
        first = bm[1][..., None, :]                    # slot 0
        last = fm[dc - 2][..., None, :]                # slot dc-1
        if dc > 2:
            fstack = jnp.stack(fm[:dc - 2], axis=-2)   # (..., dc-2, p)
            bstack = jnp.stack(bm[2:], axis=-2)        # (..., dc-2, p)
            mid = conv(fstack, bstack, p)
            ext = jnp.concatenate([first, mid, last], axis=-2)
        else:
            ext = jnp.concatenate([first, last], axis=-2)
        # check constraint: sum of contributions == 0  =>  this slot's
        # contribution must be the *negative* of the others' sum ("reflected
        # to its reverse element", paper §3.2.2)
        return _reflect(ext, p)

    return cn_fbp


_cn_fbp_jnp = _cn_fbp_make(maxplus_conv)
_cn_fbp_jnp_ref = _cn_fbp_make(maxplus_conv_ref)


def _vn_edge_table(code: LDPCCode):
    """VN-centric gather table: for each VN, the flat edge ids (cn*dc + slot)
    of its incident edges, padded with `n_edges` (a dedicated zero row).

    Lets the VN total be a gather+sum instead of a scatter-add.
    """
    c, dc = code.cn_vns.shape
    deg = np.zeros(code.n, dtype=np.int64)
    for ci in range(c):
        for s in range(dc):
            if code.cn_mask[ci, s]:
                deg[code.cn_vns[ci, s]] += 1
    dv_max = int(deg.max()) if code.n else 0
    table = np.full((code.n, dv_max), c * dc, dtype=np.int32)
    fill = np.zeros(code.n, dtype=np.int64)
    for ci in range(c):
        for s in range(dc):
            if code.cn_mask[ci, s]:
                v = code.cn_vns[ci, s]
                table[v, fill[v]] = ci * dc + s
                fill[v] += 1
    return table


# identity-keyed cache (LDPCCode holds ndarrays, so it is not hashable);
# the strong reference to `code` keeps ids from being reused. Entries are
# plain numpy so they are trace-safe: each jit lifts them as fresh constants
# (caching jnp arrays here would leak tracers across jit boundaries).
# FIFO-bounded so sweeping many code constructions can't leak memory.
_EDGE_CONSTS_CACHE: dict = {}
_EDGE_CONSTS_CACHE_MAX = 64


def _edge_consts(code: LDPCCode):
    cached = _EDGE_CONSTS_CACHE.get(id(code))
    if cached is not None and cached[0] is code:
        return cached[1]
    while len(_EDGE_CONSTS_CACHE) >= _EDGE_CONSTS_CACHE_MAX:
        _EDGE_CONSTS_CACHE.pop(next(iter(_EDGE_CONSTS_CACHE)))
    consts = dict(
        cn_vns=np.asarray(code.cn_vns, np.int32),
        cn_mask=np.asarray(code.cn_mask),
        to_contrib=np.asarray(code.perm_to_contrib, np.int32),
        to_sym=np.asarray(code.perm_to_sym, np.int32),
        H=np.asarray(code.H, np.int32),
        vn_edges=_vn_edge_table(code),
    )
    _EDGE_CONSTS_CACHE[id(code)] = (code, consts)
    return consts


def _one_iteration(code: LDPCCode, consts, prior, msgs_cv, cn_fbp: Callable):
    p = code.p
    B = prior.shape[0]
    c, dc = code.c, code.dc_max
    safe_vns = jnp.where(consts["cn_mask"], consts["cn_vns"], 0)       # (c, dc)

    # ---- VN total = prior + sum of incoming CN messages (edge gather) ----
    flat = msgs_cv.reshape(B, c * dc, p)
    flat = jnp.concatenate([flat, jnp.zeros((B, 1, p), flat.dtype)], axis=1)
    totals = prior + flat[:, consts["vn_edges"]].sum(axis=2)           # (B, n, p)

    # ---- VN -> CN extrinsic messages (padded slots masked out below) -----
    m_vc = totals[:, safe_vns] - msgs_cv                               # (B, c, dc, p)
    m_vc = normalize_llv(m_vc)

    # ---- permute to contribution space (paper Eq. 6) ----------------------
    idx = jnp.broadcast_to(consts["to_contrib"], (B, c, dc, p))
    m_hat = jnp.take_along_axis(m_vc, idx, axis=-1)
    m_hat = jnp.where(consts["cn_mask"][None, :, :, None], m_hat,
                      _identity_msg((B, c, dc), p, m_vc.dtype))

    # ---- CN forward-backward propagation ----------------------------------
    ext = cn_fbp(m_hat, p)                                             # (B, c, dc, p)

    # ---- back to symbol space + normalize ---------------------------------
    idx2 = jnp.broadcast_to(consts["to_sym"], (B, c, dc, p))
    msgs_new = jnp.take_along_axis(ext, idx2, axis=-1)
    msgs_new = normalize_llv(msgs_new)
    msgs_new = jnp.where(consts["cn_mask"][None, :, :, None], msgs_new, 0.0)

    return msgs_new, totals


def decode_llv(code: LDPCCode, prior: jnp.ndarray, *, n_iters: int = 10,
               early_exit: bool = False, damping: float = 0.0,
               cn_fbp: Callable | None = None) -> DecodeResult:
    """Iteratively decode from prior LLVs. prior: (B, n, p).

    damping in [0, 1): new messages are blended with the previous iteration's
    (msgs <- (1-d)·new + d·old), a standard stabilizer for max-log NB-LDPC
    flooding schedules on graphs with short cycles.

    early_exit=True decodes under a per-codeword converged mask (see the
    module docstring): finished codewords freeze, the loop exits as soon as
    the whole batch has converged, and `result.iterations[b]` reports the
    iterations codeword b consumed.
    """
    consts = _edge_consts(code)
    cn_fbp = cn_fbp or _cn_fbp_jnp
    B = prior.shape[0]
    msgs0 = jnp.zeros((B, code.c, code.dc_max, code.p), prior.dtype)

    def hard(totals):
        return jnp.argmax(totals, axis=-1).astype(jnp.int32)

    def synd_fail(totals):
        s = (hard(totals) @ consts["H"].T) % code.p
        return (s != 0).any(axis=-1)                                   # (B,)

    def step(msgs):
        new, totals = _one_iteration(code, consts, prior, msgs, cn_fbp)
        if damping > 0.0:
            new = (1.0 - damping) * new + damping * msgs
        return new, totals

    if not early_exit:
        def body(carry, _):
            msgs, _t = carry
            return step(msgs), None

        # run one iteration eagerly to get totals shape, then scan the rest
        msgs, totals = step(msgs0)
        if n_iters > 1:
            (msgs, totals), _ = jax.lax.scan(body, (msgs, totals), None,
                                             length=n_iters - 1)
        dec = hard(totals)
        return DecodeResult(dec, totals, synd_fail(totals),
                            jnp.full((B,), n_iters, jnp.int32))

    def cond(state):
        it, _msgs, _totals, done, _iters = state
        return (it < n_iters) & ~done.all()

    def body(state):
        it, msgs, totals, done, iters = state
        new_msgs, new_totals = step(msgs)
        # converged-mask freeze: finished codewords keep their state
        keep = done[:, None, None, None]
        msgs = jnp.where(keep, msgs, new_msgs)
        totals = jnp.where(done[:, None, None], totals, new_totals)
        it = it + 1
        iters = jnp.where(done, iters, it)
        done = done | ~synd_fail(totals)
        return (it, msgs, totals, done, iters)

    # iteration 0 computes initial totals (pure prior + zero messages)
    msgs, totals = step(msgs0)
    done0 = ~synd_fail(totals)
    state = (jnp.asarray(1, jnp.int32), msgs, totals, done0,
             jnp.ones((B,), jnp.int32))
    _, msgs, totals, done, iters = jax.lax.while_loop(cond, body, state)
    dec = hard(totals)
    return DecodeResult(dec, totals, synd_fail(totals), iters)


def decode_integers(code: LDPCCode, y: jnp.ndarray, *, n_iters: int = 10,
                    llv_scale: float = 4.0, llv_mode: str = "manhattan",
                    early_exit: bool = False, damping: float = 0.0,
                    cn_fbp: Callable | None = None):
    """Full arithmetic-code pipeline for received integer words y (B, n):
    LLV init -> iterative decode -> nearest-representative reinterpretation.

    Returns (y_corrected (B, n) ints, DecodeResult).
    """
    prior = init_llv(y, code.p, scale=llv_scale, mode=llv_mode)
    res = decode_llv(code, prior, n_iters=n_iters, early_exit=early_exit,
                     damping=damping, cn_fbp=cn_fbp)
    y_corr = reinterpret(y, res.symbols, code.p)
    if sanitizer_enabled():
        # No range check on `y`: received words are raw arithmetic levels
        # that legitimately drift outside [0, p) (the MLC failure model the
        # Manhattan/Gaussian LLV init exists for). The GF-alphabet invariant
        # holds for what the decoder *produces*; the LLV totals must stay
        # finite or the max-plus recurrence was poisoned.
        check_gf_symbols(res.symbols, code.p, "decode_integers symbols")
        check_finite(res.llv_totals, "decode_integers llv totals")
    _observe_decode(res, n_iters)
    return y_corr, res


def _observe_decode(res, n_iters: int) -> None:
    """Feed an eager decode's iteration/fail telemetry to the ambient RAS
    estimator. `decode_integers` usually runs under `jax.jit`, where the
    result fields are tracers — observation must happen at the eager call
    sites that see concrete values (the memory controller and page stores
    do their own feeding there), so tracer values are skipped outright."""
    from repro.obs import ras as obs_ras
    est = obs_ras.current()
    if not est.enabled:
        return
    iters = getattr(res, "iterations", None)
    if iters is None or isinstance(iters, jax.core.Tracer) \
            or isinstance(res.detect_fail, jax.core.Tracer):
        return
    est.observe_decode(np.asarray(iters), n_iters,
                       detect_fail=np.asarray(res.detect_fail))
