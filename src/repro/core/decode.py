"""Vectorized NB-LDPC max-log decoder (paper §3.2).

Flooding-schedule message passing over the Tanner graph of H_C:
  VN i --(coef h)--> CN j carries an LLV vector over GF(p);
  CNs run Forward-Backward Propagation (FBP): cyclic *max-plus* convolutions
  over the group (GF(p), +)  (paper Eq. 7);
  VNs accumulate prior + extrinsic messages and take argmax (paper §3.2.3).

All state is batched: `B` codewords decode simultaneously; shapes are
  prior   (B, n, p)
  msgs_cv (B, c, dc, p)   CN->VN messages in each VN's symbol space
The heavy CN inner loop can be dispatched to the Pallas `fbp` kernel
(`repro.kernels.ops.fbp_cn`) or run as pure jnp (the reference path).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .construction import LDPCCode
from .llv import NEG_INF, init_llv, reinterpret

__all__ = ["DecodeResult", "decode_llv", "decode_integers", "maxplus_conv"]


class DecodeResult(NamedTuple):
    symbols: jnp.ndarray        # (B, n) hard decisions in GF(p)
    llv_totals: jnp.ndarray     # (B, n, p) final accumulated LLVs
    detect_fail: jnp.ndarray    # (B,) True if final syndrome still nonzero
    iterations: jnp.ndarray     # () number of iterations executed


def maxplus_conv(a, b, p: int):
    """Cyclic max-plus convolution along the last (GF) axis — paper Eq. 7:
    out[k] = max_j a[(k - j) % p] + b[j]."""
    outs = []
    for k in range(p):
        terms = [a[..., (k - j) % p] + b[..., j] for j in range(p)]
        outs.append(functools.reduce(jnp.maximum, terms))
    return jnp.stack(outs, axis=-1)


def _identity_msg(shape, p: int, dtype=jnp.float32):
    e = jnp.full(shape + (p,), NEG_INF, dtype=dtype)
    return e.at[..., 0].set(0.0)


def _cn_fbp_jnp(m_hat, p: int):
    """Reference FBP over the slot axis.

    m_hat: (B, c, dc, p) messages in *contribution* space (padded slots must
    already hold the max-plus identity).  Returns extrinsic L'' per slot,
    still in contribution space but already reflected (k -> -k), shape
    (B, c, dc, p).
    """
    dc = m_hat.shape[-2]
    fm = [m_hat[..., 0, :]]
    for t in range(1, dc):
        fm.append(maxplus_conv(fm[-1], m_hat[..., t, :], p))
    bm = [m_hat[..., dc - 1, :]]
    for t in range(dc - 2, -1, -1):
        bm.append(maxplus_conv(m_hat[..., t, :], bm[-1], p))
    bm = bm[::-1]                      # bm[t] = conv of slots t..dc-1

    outs = []
    for t in range(dc):
        if t == 0:
            ext = bm[1] if dc > 1 else _identity_msg(m_hat.shape[:-2], p, m_hat.dtype)
        elif t == dc - 1:
            ext = fm[dc - 2]
        else:
            ext = maxplus_conv(fm[t - 1], bm[t + 1], p)
        outs.append(ext)
    ext = jnp.stack(outs, axis=-2)     # (B, c, dc, p): distribution of sum of others
    # check constraint: sum of contributions == 0  =>  this slot's contribution
    # must be the *negative* of the others' sum ("reflected to its reverse
    # element", paper §3.2.2)
    refl_idx = (-jnp.arange(p)) % p
    return ext[..., refl_idx]


def _edge_consts(code: LDPCCode):
    return dict(
        cn_vns=jnp.asarray(code.cn_vns, jnp.int32),
        cn_mask=jnp.asarray(code.cn_mask),
        to_contrib=jnp.asarray(code.perm_to_contrib, jnp.int32),
        to_sym=jnp.asarray(code.perm_to_sym, jnp.int32),
        H=jnp.asarray(code.H, jnp.int32),
    )


def _one_iteration(code: LDPCCode, consts, prior, msgs_cv, cn_fbp: Callable):
    p = code.p
    B = prior.shape[0]
    n, c, dc = code.n, code.c, code.dc_max
    safe_vns = jnp.where(consts["cn_mask"], consts["cn_vns"], n)      # (c, dc)

    # ---- VN total = prior + sum of incoming CN messages (scatter-add) ----
    flat_ids = safe_vns.reshape(-1)                                    # (c*dc,)
    totals = jnp.zeros((B, n + 1, p), prior.dtype)
    totals = totals.at[:, flat_ids].add(msgs_cv.reshape(B, c * dc, p))
    totals = totals.at[:, :n].add(prior)

    # ---- VN -> CN extrinsic messages -------------------------------------
    m_vc = totals[:, safe_vns] - msgs_cv                               # (B, c, dc, p)
    m_vc = m_vc - m_vc.max(axis=-1, keepdims=True)                     # normalize

    # ---- permute to contribution space (paper Eq. 6) ----------------------
    idx = jnp.broadcast_to(consts["to_contrib"], (B, c, dc, p))
    m_hat = jnp.take_along_axis(m_vc, idx, axis=-1)
    m_hat = jnp.where(consts["cn_mask"][None, :, :, None], m_hat,
                      _identity_msg((B, c, dc), p, m_vc.dtype))

    # ---- CN forward-backward propagation ----------------------------------
    ext = cn_fbp(m_hat, p)                                             # (B, c, dc, p)

    # ---- back to symbol space + normalize ---------------------------------
    idx2 = jnp.broadcast_to(consts["to_sym"], (B, c, dc, p))
    msgs_new = jnp.take_along_axis(ext, idx2, axis=-1)
    msgs_new = msgs_new - msgs_new.max(axis=-1, keepdims=True)
    msgs_new = jnp.where(consts["cn_mask"][None, :, :, None], msgs_new, 0.0)

    final_totals = totals[:, :n]
    return msgs_new, final_totals


def decode_llv(code: LDPCCode, prior: jnp.ndarray, *, n_iters: int = 10,
               early_exit: bool = False, damping: float = 0.0,
               cn_fbp: Optional[Callable] = None) -> DecodeResult:
    """Iteratively decode from prior LLVs. prior: (B, n, p).

    damping in [0, 1): new messages are blended with the previous iteration's
    (msgs <- (1-d)·new + d·old), a standard stabilizer for max-log NB-LDPC
    flooding schedules on graphs with short cycles.
    """
    consts = _edge_consts(code)
    cn_fbp = cn_fbp or _cn_fbp_jnp
    B = prior.shape[0]
    msgs0 = jnp.zeros((B, code.c, code.dc_max, code.p), prior.dtype)

    def hard(totals):
        return jnp.argmax(totals, axis=-1).astype(jnp.int32)

    def synd_fail(totals):
        s = (hard(totals) @ consts["H"].T) % code.p
        return (s != 0).any(axis=-1)                                   # (B,)

    def step(msgs):
        new, totals = _one_iteration(code, consts, prior, msgs, cn_fbp)
        if damping > 0.0:
            new = (1.0 - damping) * new + damping * msgs
        return new, totals

    if not early_exit:
        def body(carry, _):
            msgs, _t = carry
            return step(msgs), None

        # run one iteration eagerly to get totals shape, then scan the rest
        msgs, totals = step(msgs0)
        if n_iters > 1:
            (msgs, totals), _ = jax.lax.scan(body, (msgs, totals), None,
                                             length=n_iters - 1)
        dec = hard(totals)
        return DecodeResult(dec, totals, synd_fail(totals),
                            jnp.asarray(n_iters, jnp.int32))

    def cond(state):
        it, _msgs, totals = state
        return (it < n_iters) & synd_fail(totals).any()

    def body(state):
        it, msgs, _ = state
        msgs, totals = step(msgs)
        return (it + 1, msgs, totals)

    # iteration 0 computes initial totals (pure prior + zero messages)
    msgs, totals = step(msgs0)
    it, msgs, totals = jax.lax.while_loop(cond, body, (jnp.asarray(1, jnp.int32),
                                                       msgs, totals))
    dec = hard(totals)
    return DecodeResult(dec, totals, synd_fail(totals), it)


def decode_integers(code: LDPCCode, y: jnp.ndarray, *, n_iters: int = 10,
                    llv_scale: float = 4.0, llv_mode: str = "manhattan",
                    early_exit: bool = False, damping: float = 0.0,
                    cn_fbp: Optional[Callable] = None):
    """Full arithmetic-code pipeline for received integer words y (B, n):
    LLV init -> iterative decode -> nearest-representative reinterpretation.

    Returns (y_corrected (B, n) ints, DecodeResult).
    """
    prior = init_llv(y, code.p, scale=llv_scale, mode=llv_mode)
    res = decode_llv(code, prior, n_iters=n_iters, early_exit=early_exit,
                     damping=damping, cn_fbp=cn_fbp)
    y_corr = reinterpret(y, res.symbols, code.p)
    return y_corr, res
