"""Logarithmic Likelihood Value (LLV) initialization and arithmetic
re-interpretation (paper §3.2.1 and §3.2.3).

LLV convention: larger = more likely (log domain). Vectors are length-p along
the last axis, one entry per field element k ∈ GF(p).
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def normalize_llv(x):
    """Shift an LLV vector so its max entry is 0 (log-domain normalization).

    Message-passing only ever compares LLV entries, so subtracting the
    per-vector max changes nothing semantically while keeping magnitudes
    bounded across decoder iterations (float32-safe for any n_iters).
    """
    return x - x.max(axis=-1, keepdims=True)


def circular_distance(y, p: int):
    """d[..., k] = min_{z ≡ k (mod p)} |y - z| — the 1-D Manhattan distance of a
    received (integer or analog) value to the nearest representative of each
    residue class (paper Fig. 3(b))."""
    ks = jnp.arange(p, dtype=y.dtype if jnp.issubdtype(y.dtype, jnp.floating) else jnp.int32)
    t = (ks - y[..., None]) % p          # in [0, p)
    return jnp.minimum(t, p - t)


def init_llv(y, p: int, *, scale: float = 4.0, mode: str = "manhattan"):
    """Prior LLVs for received values `y` (any shape) -> (*y.shape, p).

    mode="manhattan": paper's simplified 1-D Manhattan-distance LLV.
    mode="gaussian":  full-precision likelihood under additive Gaussian noise
                      (the baseline the paper's simplification trades against).
    """
    d = circular_distance(y.astype(jnp.float32), p)
    if mode == "manhattan":
        return -scale * d
    elif mode == "gaussian":
        return -0.5 * scale * d * d
    raise ValueError(f"unknown LLV mode {mode!r}")


def reinterpret(y, decided, p: int):
    """Paper §3.2.3: move the received integer y to the *nearest* value whose
    residue mod p equals the decoded symbol.  delta ∈ (-p/2, p/2]."""
    delta = (decided.astype(jnp.int32) - y.astype(jnp.int32)) % p
    delta = jnp.where(delta > p // 2, delta - p, delta)
    return y + delta.astype(y.dtype)
