"""PIM MAC simulation (paper §2.1, §5).

Models the analog compute path of a PIM macro:
  - weights stored as multi-level cells (GF(p) symbols / differential ternary),
  - bit-serial inputs driving wordlines,
  - bitline accumulation over `row_parallelism` rows at a time,
  - ADC quantization (few-level flash ADC) of each partial sum,
  - stochastic fault models: stored-cell symbol flips and per-sample additive
    integer errors on the accumulated output (the paper's Fig. 6(c) model:
    "fixed probability of bit flip during computation", affecting both weights
    and activations/outputs).

Noise is injected from explicit PRNG keys so every simulation is
deterministic and testable; kernels receive pre-drawn noise tensors.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PIMConfig:
    row_parallelism: int = 0          # rows accumulated per analog step; 0 = all
    adc_levels: int = 0               # 0 = ideal ADC (no clipping/rounding)
    weight_flip_rate: float = 0.0     # P[a stored cell reads as a wrong symbol]
    output_error_rate: float = 0.0    # P[an accumulated output gains ±e]
    output_error_mag: int = 1         # e: magnitude of injected output errors
    p: int = 3                        # field order (cells hold GF(p) symbols)


def flip_weights(key, W: jnp.ndarray, cfg: PIMConfig) -> jnp.ndarray:
    """Cell-read fault: each cell independently flips to a *different* symbol
    with prob weight_flip_rate (uniform over the p-1 wrong symbols), in the
    centered-lift representation."""
    if cfg.weight_flip_rate <= 0:
        return W
    kf, kv = jax.random.split(key)
    flip = jax.random.bernoulli(kf, cfg.weight_flip_rate, W.shape)
    delta = jax.random.randint(kv, W.shape, 1, cfg.p)        # 1..p-1
    Wf = (W % cfg.p + delta) % cfg.p
    Wf = jnp.where(Wf > cfg.p // 2, Wf - cfg.p, Wf)          # centered lift
    return jnp.where(flip, Wf.astype(W.dtype), W)


def perturb_output(key, Y: jnp.ndarray, cfg: PIMConfig) -> jnp.ndarray:
    """Additive integer error on MAC outputs: ±output_error_mag w.p.
    output_error_rate (sign uniform)."""
    if cfg.output_error_rate <= 0:
        return Y
    ke, ks = jax.random.split(key)
    hit = jax.random.bernoulli(ke, cfg.output_error_rate, Y.shape)
    sign = jax.random.rademacher(ks, Y.shape, dtype=jnp.int32)
    return Y + jnp.where(hit, sign * cfg.output_error_mag, 0).astype(Y.dtype)


def adc_quantize(partial: jnp.ndarray, cfg: PIMConfig) -> jnp.ndarray:
    """Flash-ADC model: clip each analog partial sum to the ADC range.

    A 2.5-bit flash ADC (paper §5) resolves ~6 levels; partial sums outside
    [-(L//2), L//2] saturate. With ideal ADC (adc_levels=0) this is identity.
    """
    if cfg.adc_levels <= 0:
        return partial
    half = cfg.adc_levels // 2
    return jnp.clip(partial, -half, half)


def pim_mac(x: jnp.ndarray, W: jnp.ndarray, cfg: PIMConfig,
            key: jax.Array | None = None) -> jnp.ndarray:
    """Simulated PIM VMM:  Y = X · W  (paper Eq. 1 / Eq. 4).

    x: (B, n_in) integers (bit-serial input values), W: (n_in, n_out) integer
    cell values (data + check columns if encoded).  Accumulation happens in
    row groups of cfg.row_parallelism with ADC quantization per group.
    """
    n_in = W.shape[0]
    if key is not None:
        kw, ko = jax.random.split(key)
        W = flip_weights(kw, W, cfg)
    x32 = x.astype(jnp.int32)
    W32 = W.astype(jnp.int32)
    R = cfg.row_parallelism if cfg.row_parallelism > 0 else n_in
    if n_in % R != 0:
        pad = R - n_in % R
        x32 = jnp.pad(x32, ((0, 0), (0, pad)))
        W32 = jnp.pad(W32, ((0, pad), (0, 0)))
        n_in = n_in + pad
    g = n_in // R
    xg = x32.reshape(x32.shape[0], g, R)
    Wg = W32.reshape(g, R, W32.shape[1])
    partial = jnp.einsum("bgr,gro->bgo", xg, Wg)           # analog partial sums
    partial = adc_quantize(partial, cfg)
    Y = partial.sum(axis=1)
    if key is not None:
        Y = perturb_output(ko, Y, cfg)
    return Y
