"""Baseline PIM ECC schemes the paper compares against (Table 2).

All three operate on the same simulated-PIM substrate as the NB-LDPC scheme
so the BER / efficiency comparisons are apples-to-apples:

- `HammingSECDED` — ASSCC'21 [3]-style: per-32-bit-word Hamming(39,32)+parity
  on *stored* data. Corrects 1 bit / detects 2 per word, memory mode only
  (PIM MAC outputs are not codewords of a binary Hamming code — exactly the
  limitation the paper targets).
- `ModuloParity` — ESSCIRC'22 [19]-style: a mod-q checksum column rides
  through the MAC (q=3 default); detects single-column errors in the output
  and corrects ±1 errors by syndrome lookup in one residue: correction is
  limited to the ±1 pattern (MTE=1).
- `SuccessiveCorrection` — DAC'22 [4]-style: detect via checksum columns,
  then *interrupt the dataflow*: re-read the PIM array row-group by
  row-group (digital recompute) to localize and fix errors; corrects up to
  `max_rereads` errors at a dataflow-interruption cost we charge in the
  efficiency model (MTE=3 at the paper's settings).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Hamming (39,32) SECDED — memory mode
# ---------------------------------------------------------------------------

_H_R = 7   # 6 hamming bits + 1 overall parity protect 32 data bits


def _hamming_positions(n_data: int = 32):
    """Positions (1-indexed, power-of-two slots are parity) for data bits."""
    pos, i = [], 1
    while len(pos) < n_data:
        i += 1
        if i & (i - 1):
            pos.append(i)
    return np.asarray(pos, np.int64)


@dataclasses.dataclass(frozen=True)
class HammingSECDED:
    n_data: int = 32

    def encode(self, bits: np.ndarray) -> np.ndarray:
        """bits: (..., 32) in {0,1} -> (..., 39) [6 parity | 32 data | 1 all]."""
        pos = _hamming_positions(self.n_data)
        nbits = int(pos.max())
        word = np.zeros(bits.shape[:-1] + (nbits + 1,), np.int64)
        word[..., pos - 1] = bits
        for j in range(6):
            pbit = 1 << j
            mask = ((np.arange(1, nbits + 1) & pbit) > 0)
            word[..., pbit - 1] = word[..., :nbits][..., mask].sum(-1) % 2
        word[..., -1] = word[..., :-1].sum(-1) % 2
        return word

    def decode(self, word: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """-> (corrected data bits, uncorrectable flag)."""
        pos = _hamming_positions(self.n_data)
        nbits = word.shape[-1] - 1
        synd = np.zeros(word.shape[:-1], np.int64)
        for j in range(6):
            pbit = 1 << j
            mask = ((np.arange(1, nbits + 1) & pbit) > 0)
            synd += pbit * (word[..., :nbits][..., mask].sum(-1) % 2)
        parity = word.sum(-1) % 2
        corrected = word.copy()
        err = synd > 0
        idx = np.clip(synd - 1, 0, nbits - 1)
        flat = corrected.reshape(-1, word.shape[-1])
        fe, fi = err.reshape(-1), idx.reshape(-1)
        flat[np.arange(flat.shape[0])[fe], fi[fe]] ^= 1
        corrected = flat.reshape(word.shape)
        # single error: synd>0 & parity=1 (fixed). double: synd>0 & parity=0.
        uncorrectable = (synd > 0) & (parity == 0)
        return corrected[..., pos - 1], uncorrectable


# ---------------------------------------------------------------------------
# Modulo checksum column (rides through the MAC) — detect + ±1 correct
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModuloParity:
    q: int = 3

    def encode_weights(self, W: jnp.ndarray) -> jnp.ndarray:
        """Append one checksum column: sum of data columns mod q, centered."""
        chk = jnp.sum(W.astype(jnp.int32), axis=1, keepdims=True) % self.q
        chk = jnp.where(chk > self.q // 2, chk - self.q, chk)
        return jnp.concatenate([W.astype(jnp.int32), chk], axis=1)

    def detect(self, Y: jnp.ndarray) -> jnp.ndarray:
        """Y: (..., n+1) MAC outputs incl. checksum col -> error flags."""
        s = (jnp.sum(Y[..., :-1].astype(jnp.int32), -1)
             - Y[..., -1].astype(jnp.int32)) % self.q
        return s != 0

    def correct(self, Y: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """±1 single-error correction: if the residue mismatch is ±1 mod q and
        exactly one column is implicated (unknowable without more structure —
        the scheme can only fix errors in the *checksum* residue class),
        adjust the worst-offending column. Returns (data, uncorrected)."""
        data = Y[..., :-1].astype(jnp.int32)
        s = (jnp.sum(data, -1) - Y[..., -1].astype(jnp.int32)) % self.q
        delta = jnp.where(s > self.q // 2, s - self.q, s)      # centered
        fixable = jnp.abs(delta) == 1
        # heuristic localization: the column farthest from its rounded value
        # is unavailable in integer outputs — charge the error to col 0 like
        # the LUT schemes do for their supported pattern; everything else is
        # "detected, uncorrected".
        corrected = data.at[..., 0].add(-jnp.where(fixable, delta, 0))
        return corrected, (s != 0) & ~fixable


# ---------------------------------------------------------------------------
# Successive correction (re-read; interrupts dataflow)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SuccessiveCorrection:
    q: int = 3
    max_rereads: int = 3

    def correct(self, x: jnp.ndarray, W_true: jnp.ndarray, Y: jnp.ndarray,
                row_group: int = 32):
        """Detect residue mismatches column-wise, then recompute the guilty
        columns digitally from the stored weights (the 're-read'): exact fix,
        at the cost of interrupting the PIM dataflow. Returns (Y_fixed,
        n_rereads) — the reread count feeds the efficiency model."""
        exact = (x.astype(jnp.int32) @ W_true.astype(jnp.int32))
        bad = Y != exact                             # oracle detect via reread
        ncols = jnp.minimum(bad.any(0).sum(), self.max_rereads)
        col_bad = bad.any(axis=0)
        rank = jnp.cumsum(col_bad) - 1
        fix = col_bad & (rank < self.max_rereads)
        Yf = jnp.where(fix[None, :], exact, Y)
        return Yf, ncols
