"""PIMContext — routes model projections through the PIM + NB-LDPC path.

This is the deployment integration of the paper's technique: a target matmul
(e.g. `mlp_down`, `attn_o`) executes as
  1. ternarize weights (differential mapping, paper §3.3) + quantize
     activations to small integers,
  2. NB-LDPC-encode the weight columns (check columns ride along, Fig. 2(b)),
  3. simulated PIM MAC over data+check columns (noise injected when a fault
     key is supplied — Eq. 4),
  4. syndrome detect + iterative FBP correction on the integer outputs
     (Eq. 5, §3.2), drop check columns,
  5. dequantize back to the activation dtype.

Codeword blocks are sized to divide the *per-shard* output width, so under
tensor parallelism every decode is shard-local (no collectives) — the TPU
analogue of the paper's N_P-cores-per-decoder sharing (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import PIMSpec
from .codes import get_code
from .pim import PIMConfig
from .protected import (ProtectionConfig, protected_pim_matmul,
                        protected_pim_matmul_budgeted, prepare_weights)


class PIMContext:
    def __init__(self, spec: PIMSpec, key: jax.Array | None = None,
                 act_levels: int = 7):
        self.spec = spec
        self.targets = set(spec.targets)
        self.code = get_code(spec.code_name)
        self.key = key
        self.act_levels = act_levels
        self.prot = ProtectionConfig(
            code_name=spec.code_name, mode=spec.mode, n_iters=spec.n_iters,
            damping=spec.damping)
        self.pim_cfg = PIMConfig(
            row_parallelism=spec.row_parallelism, adc_levels=spec.adc_levels,
            p=self.code.p,
            output_error_rate=0.0)  # noise enters via explicit fault keys
        self._fault_cfg = None      # set by with_faults()
        if spec.use_kernels:
            from repro.kernels.ops import fbp_cn_batched
            self.cn_fbp = fbp_cn_batched
        else:
            self.cn_fbp = None

    def with_faults(self, key: jax.Array, output_error_rate: float,
                    weight_flip_rate: float = 0.0):
        """Return a context that injects stochastic PIM faults (Fig. 6(c))."""
        other = PIMContext.__new__(PIMContext)
        other.__dict__.update(self.__dict__)
        other.key = key
        other._fault_cfg = dataclasses.replace(
            self.pim_cfg, output_error_rate=output_error_rate,
            weight_flip_rate=weight_flip_rate)
        return other

    # -- quantization ------------------------------------------------------

    @staticmethod
    def ternarize(W: jnp.ndarray, thresh: float = 0.7):
        """Differential ternary mapping: W -> {-1, 0, +1} * alpha.
        alpha = E|W| over the kept entries (TWN-style)."""
        Wf = W.astype(jnp.float32)
        t = thresh * jnp.mean(jnp.abs(Wf))
        Wq = jnp.where(Wf > t, 1, jnp.where(Wf < -t, -1, 0)).astype(jnp.int32)
        nz = jnp.maximum((Wq != 0).sum(), 1)
        alpha = jnp.sum(jnp.abs(Wf) * (Wq != 0)) / nz
        return Wq, alpha

    def quantize_acts(self, x: jnp.ndarray):
        """Symmetric integer quantization of activations to ±act_levels."""
        xf = x.astype(jnp.float32)
        s = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-6) / self.act_levels
        xq = jnp.clip(jnp.round(xf / s), -self.act_levels,
                      self.act_levels).astype(jnp.int32)
        return xq, s

    # -- the protected matmul ---------------------------------------------

    def encode_weight(self, W: jnp.ndarray):
        """Deploy-time: ternarize + NB-LDPC-encode. Returns (int8 W_enc,
        fp32 alpha). Stored as params so serving never re-encodes."""
        Wq, alpha = self.ternarize(W)
        W_enc = prepare_weights(Wq, self.code)
        return W_enc.astype(jnp.int8), alpha.astype(jnp.float32)

    def matmul(self, x: jnp.ndarray, W: jnp.ndarray, name: str,
               enc: jnp.ndarray | None = None,
               alpha: jnp.ndarray | None = None) -> jnp.ndarray:
        """x: (..., n_in) activations; W: (n_in, n_out) fp weights.
        Returns (..., n_out) in x.dtype via the protected PIM path.
        With `enc`/`alpha` (precoded deployment) the fp weights are not
        touched at all — the PIM array holds the encoded integers."""
        orig_shape = x.shape
        orig_dtype = x.dtype
        n_out = W.shape[1]
        x2 = x.reshape(-1, orig_shape[-1])

        if enc is not None:
            W_enc = enc.astype(jnp.int32)
            xq, s = self.quantize_acts(x2)
        else:
            Wq, alpha = self.ternarize(W)
            xq, s = self.quantize_acts(x2)
            W_enc = prepare_weights(Wq, self.code)        # pad + encode

        pim_cfg = self._fault_cfg or self.pim_cfg
        key = self.key if self._fault_cfg is not None else None
        if self.spec.mode == "correct_budget":
            prot = dataclasses.replace(self.prot, mode="correct")
            res = protected_pim_matmul_budgeted(
                xq, W_enc, self.code, prot, pim_cfg, key=key,
                budget=self.spec.correct_budget, cn_fbp=self.cn_fbp)
        else:
            res = protected_pim_matmul(xq, W_enc, self.code, self.prot,
                                       pim_cfg, key=key, cn_fbp=self.cn_fbp)
        y = res.y[:, :n_out].astype(jnp.float32) * (s * alpha)
        return y.reshape(orig_shape[:-1] + (n_out,)).astype(orig_dtype)
