"""Registry of standard NB-LDPC code configurations used across the framework.

Mirrors the paper's evaluated design points:
  - prototype chip: word length 256, code rate 0.8, GF(3)  (paper §5, §6.2)
  - Fig 6(a): word lengths 32..1024 at rate 0.8
  - Fig 6(b): word length 512 at rates 0.33..0.8
  - max-rate point: word length 1024 at rate 0.88 (paper abstract / §6.3)
"""
from __future__ import annotations

import functools

from .construction import LDPCCode, build_code

# name -> (n, k, p, dv)
REGISTRY = {
    "chip256_r08": (256, 205, 3, 3),      # silicon prototype point
    "wl32_r08": (32, 26, 3, 3),
    "wl64_r08": (64, 51, 3, 3),
    "wl128_r08": (128, 102, 3, 3),
    "wl256_r08": (256, 205, 3, 3),
    "wl512_r08": (512, 410, 3, 3),
    "wl1024_r08": (1024, 819, 3, 3),
    "wl1024_r088": (1024, 902, 3, 3),     # >88% code rate headline point
    "wl512_r033": (512, 169, 3, 3),
    "wl512_r05": (512, 256, 3, 3),
    "wl512_r067": (512, 343, 3, 3),
    # small codes for model-layer protection & tests (keep per-layer padding low)
    "wl40_r08": (40, 32, 3, 3),
    "wl80_r08": (80, 64, 3, 3),
    "wl160_r08": (160, 128, 3, 3),
    "wl320_r08": (320, 256, 3, 3),
    # multi-level-cell variants (paper §3.3: MLC support via larger GF(p))
    "wl160_r08_gf5": (160, 128, 5, 3),
    "wl160_r08_gf7": (160, 128, 7, 3),
}


@functools.lru_cache(maxsize=64)
def get_code(name: str, seed: int = 0) -> LDPCCode:
    if name not in REGISTRY:
        raise KeyError(f"unknown code {name!r}; available: {sorted(REGISTRY)}")
    n, k, p, dv = REGISTRY[name]
    return build_code(n, k, p=p, dv=dv, seed=seed)
