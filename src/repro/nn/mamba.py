"""Mamba-1 block (selective SSM) — falcon-mamba-7b / jamba hybrid layers.

Training/prefill runs a **chunked selective scan**: time is split into chunks;
`lax.scan` carries the (d_inner, d_state) SSM state across chunks while the
affine recurrence inside a chunk is evaluated with `lax.associative_scan`
(h_t = a_t · h_{t-1} + b_t composes associatively). This bounds the in-flight
(B, chunk, d_inner, d_state) expansion to one chunk — the TPU analogue of the
fused CUDA selective-scan kernel's tiling.

Decode is a single recurrence step on carried state (SSM state + conv tail),
O(1) in context length — which is why the `long_500k` cell runs for SSM/hybrid
architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain

CDT = jnp.bfloat16


class MambaState(NamedTuple):
    conv: jnp.ndarray     # (B, d_conv - 1, d_inner) trailing conv inputs
    ssm: jnp.ndarray      # (B, d_inner, d_state) recurrent state (fp32)


def init_mamba(key, cfg: ArchConfig):
    d, di, ds, dtr, dconv = (cfg.d_model, cfg.d_inner, cfg.d_state,
                             cfg.dt_rank, cfg.d_conv)
    ks = jax.random.split(key, 6)
    s = 0.02
    # S4D-real initialization for A: A[n] = -(n+1), broadcast across channels
    A_log = jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                     (di, ds)))
    return {
        "in_proj": s * jax.random.normal(ks[0], (d, 2 * di), jnp.float32),
        "conv_w": s * jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": s * jax.random.normal(ks[2], (di, dtr + 2 * ds), jnp.float32),
        "dt_proj_w": s * jax.random.normal(ks[3], (dtr, di), jnp.float32),
        "dt_proj_b": jnp.full((di,), -4.6, jnp.float32),   # softplus^-1(~0.01)
        "A_log": A_log,
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": s * jax.random.normal(ks[5], (di, d), jnp.float32),
    }


def mamba_param_axes():
    """Logical sharding axes parallel to init_mamba's tree (d_inner -> TP)."""
    return {
        "in_proj": ("d_model", "d_inner"),
        "conv_w": (None, "d_inner"),
        "conv_b": ("d_inner",),
        "x_proj": ("d_inner", None),
        "dt_proj_w": (None, "d_inner"),
        "dt_proj_b": ("d_inner",),
        "A_log": ("d_inner", None),
        "D": ("d_inner",),
        "out_proj": ("d_inner", "d_model"),
    }


def _causal_conv_full(x, w, b, tail=None):
    """Depthwise causal conv over time. x: (B, L, di), w: (K, di).
    `tail`: (B, K-1, di) carried inputs from the previous segment (decode) or
    zeros (sequence start). Returns conv output and the new tail."""
    K = w.shape[0]
    B, L, di = x.shape
    if tail is None:
        tail = jnp.zeros((B, K - 1, di), x.dtype)
    xc = jnp.concatenate([tail, x], axis=1)               # (B, L+K-1, di)
    out = jnp.zeros((B, L, di), jnp.float32)
    for k in range(K):                                    # K is 4: unrolled taps
        out = out + xc[:, k:k + L].astype(jnp.float32) * w[k]
    new_tail = xc[:, -(K - 1):] if K > 1 else jnp.zeros((B, 0, di), x.dtype)
    return (out + b).astype(x.dtype), new_tail


def _ssm_chunked(u, delta, A, Bm, Cm, D, h0, chunk: int):
    """Selective scan, chunked. u/delta: (B, L, di); Bm/Cm: (B, L, ds);
    A: (di, ds) negative reals; h0: (B, di, ds) fp32. Returns (y, hL)."""
    B, L, di = u.shape
    ds = A.shape[1]
    nch = L // chunk
    assert nch * chunk == L, f"L={L} not divisible by chunk={chunk}"

    # fold time into (nch, chunk)
    def fold(t):
        return t.reshape(B, nch, chunk, *t.shape[2:]).swapaxes(0, 1)

    uf, df, Bf, Cf = fold(u), fold(delta), fold(Bm), fold(Cm)

    def chunk_step(h, xs):
        uc, dc, Bc, Cc = xs                                # (B, chunk, ...)
        dA = jnp.exp(dc[..., None] * A)                    # (B, c, di, ds)
        dBu = (dc * uc)[..., None] * Bc[:, :, None, :]     # (B, c, di, ds)

        # affine composition: (a2,b2)∘(a1,b1) = (a1·a2, a2·b1 + b2)
        def comb(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(comb, (dA, dBu), axis=1)
        hs = a_cum * h[:, None] + b_cum                    # (B, c, di, ds)
        yc = jnp.einsum("bcds,bcs->bcd", hs, Cc)           # (B, c, di)
        return hs[:, -1], yc

    hL, yf = jax.lax.scan(chunk_step, h0, (uf.astype(jnp.float32),
                                           df.astype(jnp.float32),
                                           Bf.astype(jnp.float32),
                                           Cf.astype(jnp.float32)))
    y = yf.swapaxes(0, 1).reshape(B, L, di)
    return y + u.astype(jnp.float32) * D, hL


def _ssm_step(u, delta, A, Bm, Cm, D, h):
    """One decode step. u/delta: (B, di); Bm/Cm: (B, ds); h: (B, di, ds)."""
    dA = jnp.exp(delta[..., None] * A)
    dBu = (delta * u)[..., None] * Bm[:, None, :]
    h = dA * h + dBu
    y = jnp.einsum("bds,bs->bd", h, Cm) + u * D
    return y, h


def mamba_apply(params, x, cfg: ArchConfig, state: MambaState | None = None,
                *, decode: bool = False):
    """x: (B, L, d_model) -> (y, new_state).

    Full-sequence mode (training / prefill): decode=False; `state` is the
    initial state (None = zeros). Decode mode: L == 1, state required.
    """
    B, L, _ = x.shape
    di, ds, dtr = cfg.d_inner, cfg.d_state, cfg.dt_rank

    xz = x @ params["in_proj"].astype(CDT)                 # (B, L, 2di)
    xz = constrain(xz, "batch", None, "d_inner")
    u, z = jnp.split(xz, 2, axis=-1)

    tail = state.conv if state is not None else None
    u_conv, new_tail = _causal_conv_full(u, params["conv_w"].astype(jnp.float32),
                                         params["conv_b"], tail)
    u_conv = jax.nn.silu(u_conv.astype(jnp.float32)).astype(CDT)
    u_conv = constrain(u_conv, "batch", None, "d_inner")

    proj = u_conv @ params["x_proj"].astype(CDT)           # (B, L, dtr+2ds)
    dt, Bm, Cm = jnp.split(proj.astype(jnp.float32), [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(dt @ params["dt_proj_w"] + params["dt_proj_b"])
    A = -jnp.exp(params["A_log"])                          # (di, ds)

    h0 = (state.ssm if state is not None
          else jnp.zeros((B, di, ds), jnp.float32))
    if decode:
        y, h = _ssm_step(u_conv[:, 0].astype(jnp.float32), delta[:, 0], A,
                         Bm[:, 0], Cm[:, 0], params["D"], h0)
        y = y[:, None]
    else:
        chunk = min(cfg.mamba_chunk, L)
        pad = (-L) % chunk
        if pad:
            zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
            y, h = _ssm_chunked(zpad(u_conv), zpad(delta), A, zpad(Bm), zpad(Cm),
                                params["D"], h0, chunk)
            y = y[:, :L]
        else:
            y, h = _ssm_chunked(u_conv, delta, A, Bm, Cm, params["D"], h0, chunk)

    y = (y.astype(CDT) * jax.nn.silu(z.astype(jnp.float32)).astype(CDT))
    y = constrain(y, "batch", None, "d_inner")
    out = y @ params["out_proj"].astype(CDT)
    return constrain(out, "batch", None, None), MambaState(new_tail, h)


def init_mamba_state(cfg: ArchConfig, batch: int) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), CDT),
        ssm=jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    )
