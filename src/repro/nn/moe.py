"""Mixture-of-Experts FFN.

Two implementations sharing one parameter layout:
  - "dense":     oracle — every expert processes every token, combine by gate
                 weights. O(E) compute; only for tests/tiny configs.
  - "sorted_ep": production — top-k routing, sort tokens by expert id, pack
                 into an (E, capacity, d) buffer (experts sharded over the
                 `model` mesh axis = expert parallelism), grouped GEMMs,
                 unsort + weighted combine. Capacity-dropped tokens fall back
                 to zero (standard dropping MoE).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from .layers import ACTS, CDT


def init_moe(key, cfg: ArchConfig, d_ff: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, E = cfg.d_model, cfg.n_experts
    s = 0.02
    return {
        "router": s * jax.random.normal(k1, (d, E), jnp.float32),
        "w_gate": s * jax.random.normal(k2, (E, d, d_ff), jnp.float32),
        "w_up": s * jax.random.normal(k3, (E, d, d_ff), jnp.float32),
        "w_down": s * jax.random.normal(k4, (E, d_ff, d), jnp.float32),
    }


def _route(params, x2d, cfg: ArchConfig):
    logits = (x2d @ params["router"].astype(CDT)).astype(jnp.float32)
    topv, topi = jax.lax.top_k(logits, cfg.top_k)
    w = jax.nn.softmax(topv, axis=-1)
    return topi.astype(jnp.int32), w.astype(CDT)


def moe_dense(params, x2d, cfg: ArchConfig):
    """Oracle: (T, d) -> (T, d) computing all experts."""
    act = ACTS[cfg.act]
    topi, w = _route(params, x2d, cfg)
    g = jnp.einsum("td,edf->tef", x2d, params["w_gate"].astype(CDT))
    u = jnp.einsum("td,edf->tef", x2d, params["w_up"].astype(CDT))
    h = act(g) * u
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"].astype(CDT))
    T = x2d.shape[0]
    sel = y_all[jnp.arange(T)[:, None], topi]           # (T, k, d)
    return (w[..., None] * sel).sum(axis=1)


def moe_sorted_ep(params, x2d, cfg: ArchConfig):
    """Production path: sort-by-expert + capacity buffer + grouped GEMM."""
    act = ACTS[cfg.act]
    T, d = x2d.shape
    E, K = cfg.n_experts, cfg.top_k
    cap = max(1, int(T * K / E * cfg.capacity_factor))

    topi, w = _route(params, x2d, cfg)                  # (T,K)
    flat_e = topi.reshape(-1)                           # (T*K,)
    order = jnp.argsort(flat_e)                         # stable
    sorted_e = flat_e[order]
    tok_of = order // K                                 # original token per slot

    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts                # exclusive prefix
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < cap
    safe_pos = jnp.where(keep, pos_in_e, cap)           # dropped -> scratch row

    # pack to (E, cap+1, d); scratch row `cap` absorbs capacity overflow
    buf = jnp.zeros((E, cap + 1, d), CDT)
    buf = buf.at[sorted_e, safe_pos].set(x2d[tok_of])
    buf = constrain(buf, "expert", "batch", None)

    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(CDT))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(CDT))
    h = act(g) * u
    h = constrain(h, "expert", "batch", None)   # d_ff unsharded: `model` is
    yb = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(CDT))
    yb = constrain(yb, "expert", "batch", None)  # the expert-parallel axis

    y_slots = yb[sorted_e, safe_pos]                    # (T*K, d)
    y_slots = jnp.where(keep[:, None], y_slots, 0)
    w_slots = w.reshape(-1)[order]
    y = jnp.zeros((T, d), CDT).at[tok_of].add(w_slots[:, None] * y_slots)
    return y


def moe_apply(params, x, cfg: ArchConfig):
    if cfg.moe_impl == "shard_ep":
        from .moe_shard import moe_shard_apply
        return constrain(moe_shard_apply(params, x, cfg),
                         "batch", None, None)
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    fn = moe_dense if cfg.moe_impl == "dense" else moe_sorted_ep
    y = fn(params, x2d, cfg)
    return constrain(y.reshape(B, S, d), "batch", None, None)
