"""Expert-parallel MoE via shard_map: explicit all-to-all dispatch.

The pjit-native sort/scatter formulation (moe.py::moe_sorted_ep) is correct
but GSPMD lowers its cross-shard scatter to full-buffer all-reduces — the
dry-run measured 9 x 8 GiB all-reduces per layer on olmoe (train_4k), making
every MoE cell collective-bound. This module is the production path:

  1. shard_map over (data..., model): each data shard routes its LOCAL tokens
     (router weights are replicated);
  2. tokens are packed locally into per-expert capacity buckets
     (E, cap_local, d) — a *local* scatter, no collective;
  3. ONE all_to_all over the `model` (expert-parallel) axis moves each bucket
     to its expert's shard — wire bytes = the tokens actually routed
     (top_k copies of each token), the information-theoretic minimum;
  4. grouped GEMM over the local experts' received buckets;
  5. the reverse all_to_all returns expert outputs; a local gather+weighted
     combine finishes.

Differentiable end to end (all_to_all and the local scatters have exact
transposes), so the same path serves training and inference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import active_mesh
from .layers import ACTS, CDT


def _local_moe(x, router, w_gate, w_up, w_down, cfg: ArchConfig, ep: int):
    """Per-shard body. x: (T_loc, d) local tokens; experts sharded: w_*
    carry E_loc = E/ep experts. Runs under shard_map with axis 'model'."""
    act = ACTS[cfg.act]
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    E_loc = w_gate.shape[0]
    cap = max(1, int(T * K / E * cfg.capacity_factor))

    # ---- route locally (router replicated) --------------------------------
    logits = (x @ router.astype(CDT)).astype(jnp.float32)      # (T, E)
    topv, topi = jax.lax.top_k(logits, K)
    gate = jax.nn.softmax(topv, axis=-1).astype(CDT)           # (T, K)

    # ---- pack into per-(global)expert capacity buckets (local scatter) ----
    flat_e = topi.reshape(-1)                                  # (T*K,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    tok_of = order // K
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < cap
    safe_pos = jnp.where(keep, pos_in_e, cap)

    send = jnp.zeros((E, cap + 1, d), CDT)
    send = send.at[sorted_e, safe_pos].set(x[tok_of])          # local only
    send = send[:, :cap]

    # ---- all_to_all over the expert-parallel axis -------------------------
    # (E, cap, d) -> split E across `ep` shards, concat the received shards:
    # recv: (E_loc * ep, cap, d) = every shard's buckets for MY experts.
    recv = jax.lax.all_to_all(send.reshape(ep, E_loc, cap, d), "model",
                              split_axis=0, concat_axis=0, tiled=False)
    recv = recv.reshape(ep, E_loc, cap, d).transpose(1, 0, 2, 3)
    recv = recv.reshape(E_loc, ep * cap, d)                    # per local exp.

    # ---- grouped GEMM over local experts -----------------------------------
    g = jnp.einsum("ecd,edf->ecf", recv, w_gate.astype(CDT))
    u = jnp.einsum("ecd,edf->ecf", recv, w_up.astype(CDT))
    h = act(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(CDT))      # (E_loc,ep*cap,d)

    # ---- return tokens to their source shards ------------------------------
    y = y.reshape(E_loc, ep, cap, d).transpose(1, 0, 2, 3)     # (ep,E_loc,...)
    back = jax.lax.all_to_all(y, "model", split_axis=0, concat_axis=0,
                              tiled=False)
    back = back.reshape(E, cap, d)                             # my tokens' outs

    # ---- local unscatter + weighted combine --------------------------------
    pad = jnp.zeros((E, 1, d), CDT)
    backp = jnp.concatenate([back, pad], axis=1)               # row `cap`=0
    y_slots = backp[sorted_e, safe_pos]                        # (T*K, d)
    y_slots = jnp.where(keep[:, None], y_slots, 0)
    gate_slots = gate.reshape(-1)[order]
    out = jnp.zeros((T, d), CDT).at[tok_of].add(
        gate_slots[:, None] * y_slots)
    return out


def moe_shard_apply(params, x, cfg: ArchConfig):
    """x: (B, S, d). Requires an active mesh with a `model` axis (EP);
    falls back to the pjit path without one (unit tests, 1-device)."""
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        from .moe import moe_apply
        return moe_apply(params, x, cfg)
    ep = mesh.shape["model"]
    B, S, d = x.shape
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")

    fn = functools.partial(_local_moe, cfg=cfg, ep=ep)
    from repro.distributed.sharding import compat_shard_map
    mapped = compat_shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp_axes, None),                # x2d: tokens over DP axes
                  P(),                             # router replicated
                  P("model", None, None),          # experts over model
                  P("model", None, None),
                  P("model", None, None)),
        out_specs=P(dp_axes, None),
    )
    y = mapped(x.reshape(B * S, d).astype(CDT), params["router"],
               params["w_gate"], params["w_up"], params["w_down"])
    return y.reshape(B, S, d)
