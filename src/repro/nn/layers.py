"""Core NN layers: norms, MLPs, rotary embeddings, GQA attention
(global / sliding-window / cross), logit soft-capping.

Functional style: `init_*` builds param pytrees (fp32), `*_apply` are pure.
Compute runs in bf16 with fp32 softmax/norm accumulation. Tensors are
annotated with logical axes via repro.distributed.sharding.constrain.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.distributed.sharding import constrain
from repro.nn.kv_source import KVSource

ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}
CDT = jnp.bfloat16      # compute dtype


def _norm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(CDT)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU family)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 0.02
    return {
        "w_gate": s * jax.random.normal(k1, (d_model, d_ff), jnp.float32),
        "w_up": s * jax.random.normal(k2, (d_model, d_ff), jnp.float32),
        "w_down": s * jax.random.normal(k3, (d_ff, d_model), jnp.float32),
    }


def mlp_apply(params, x, act="silu", pim_ctx=None, layer_name=""):
    a = ACTS[act]
    g = x @ params["w_gate"].astype(CDT)
    u = x @ params["w_up"].astype(CDT)
    h = a(g) * u
    h = constrain(h, "batch", None, "d_ff")
    if pim_ctx is not None and f"{layer_name}mlp_down" in pim_ctx.targets:
        y = pim_ctx.matmul(h, params["w_down"], "mlp_down",
                           enc=params.get("w_down_enc"),
                           alpha=params.get("w_down_alpha"))
    else:
        y = h @ params["w_down"].astype(CDT)
    return constrain(y, "batch", None, None)


# ---------------------------------------------------------------------------
# attention (GQA; optional sliding window, soft-cap, cross-attention)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = 0.02
    return {
        "wq": s * jax.random.normal(k1, (d, hq * dh), jnp.float32),
        "wk": s * jax.random.normal(k2, (d, hkv * dh), jnp.float32),
        "wv": s * jax.random.normal(k3, (d, hkv * dh), jnp.float32),
        "wo": s * jax.random.normal(k4, (hq * dh, d), jnp.float32),
    }


def _softcap(logits, cap):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _attend(q, k, v, mask, softcap, *, impl="naive", causal=True, window=0):
    """q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D), mask: broadcastable (B,1,Sq,Skv).

    impl="flash": dispatch to the Pallas flash kernel (mask expressed as
    causal/window flags — O(S*D) HBM traffic). impl="standin": cost-lowering
    placeholder with the same dataflow but no S^2 intermediates; the
    attention-internal FLOPs/bytes are added analytically (launch/costs.py).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    if impl == "flash" and Sq > 1:
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal, window,
                               float(softcap or 0.0), None, None)
    if impl == "standin" and Sq > 1:
        # keeps gradients flowing to q/k/v (projection costs stay exact)
        # while contributing ~zero attention-internal flops/bytes
        km = k.mean(axis=1, keepdims=True).mean(axis=2, keepdims=True)
        vm = v.mean(axis=1, keepdims=True).mean(axis=2, keepdims=True)
        return q + (km + vm).astype(q.dtype)
    qg = q.reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    logits = _softcap(logits / jnp.sqrt(D).astype(jnp.float32), softcap)
    logits = jnp.where(mask[:, :, None] if mask is not None else True,
                       logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(CDT)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(B, Sq, Hq, D)


@functools.partial(jax.jit, static_argnames=("softcap",))
def _paged_attn_update(q, kpg, vpg, valid, m, l, acc, softcap=0.0):
    """One online-softmax step over a KV page (flash-attention recurrence,
    page-granular). q: (B,Sq,Hq,D); kpg/vpg: (B,T,Hkv,D); valid: () or (B,)
    int32 — tokens of the page that are real per sequence (pad slots
    masked; a (B,) valid is the multi-tenant batched-slot path, where
    ragged sequences share one executable). Carries (m, l, acc) in fp32;
    fixed page shapes mean ONE cached executable serves every page of a
    layer.

    The math lives in `repro.kernels.ref.paged_softmax_update` — the same
    recurrence the fused `attend_protected` oracle replays page-by-page —
    so the streaming and fused protected read paths are bit-identical by
    construction (tests/test_fused_attention.py)."""
    from repro.kernels.ref import paged_softmax_update
    return paged_softmax_update(q, kpg, vpg, valid, m, l, acc,
                                softcap=softcap)


def _attend_paged(q, pages, softcap):
    """Streaming attention over an iterator of decoded KV pages.

    `pages` yields (k_page (B,T,Hkv,D), v_page, valid_tokens) — the
    protected KV-cache read path (`repro.models.kv.ProtectedKVLayer.pages`):
    page i+1's decode is dispatched by the generator while this loop's
    softmax/accumulate runs on page i, so ECC decode overlaps attention
    instead of interrupting it. Equivalent to `_attend` over the
    concatenated pages (online softmax is exact).
    """
    B, Sq, Hq, D = q.shape
    m = l = acc = None
    for kpg, vpg, valid in pages:
        if m is None:
            Hkv = kpg.shape[2]
            G = Hq // Hkv
            m = jnp.full((B, Hkv, G, Sq, 1), -jnp.inf, jnp.float32)
            l = jnp.zeros((B, Hkv, G, Sq, 1), jnp.float32)
            acc = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
        m, l, acc = _paged_attn_update(
            q, kpg, vpg, jnp.asarray(valid, jnp.int32), m, l, acc,
            softcap=float(softcap or 0.0))
    if m is None:
        raise ValueError("paged attention needs at least one KV page")
    out = acc / jnp.maximum(l, 1e-30)
    out = out.transpose(0, 3, 1, 2, 4)               # (B,Sq,Hkv,G,D)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def attention_apply(params, x, spec: LayerSpec, cfg: ArchConfig, *,
                    positions, kv_cache=None, cache_pos=None, aux_kv=None,
                    pim_ctx=None):
    """Self- or cross-attention.

    Training/prefill: kv_cache None -> causal full pass, returns (y, new_cache
    or None). Decode: kv_cache dict {"k","v"} (B, Smax, Hkv, D) + cache_pos
    scalar -> one-token update; a `repro.nn.kv_source.KVSource` instead
    routes the read through the source (append the token's K/V, then
    `source.attend` — the protected paged layers take the fused one-kernel
    GF-page attention path there, or stream decoded pages through
    `_attend_paged`). The legacy {"paged": layer} dict form is deprecated
    and unwraps to the same dispatch. Cross: aux_kv = precomputed (k, v).
    """
    B, S, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"].astype(CDT)).reshape(B, S, hq, dh)
    q = constrain(q, "batch", None, "heads", None)

    new_cache = None
    paged = kv_cache if isinstance(kv_cache, KVSource) else None
    if paged is None and isinstance(kv_cache, dict) and "paged" in kv_cache:
        warnings.warn(
            'kv_cache={"paged": layer} is deprecated; pass the KVSource '
            "layer itself. The dict form will be removed next release.",
            DeprecationWarning, stacklevel=2)
        paged = kv_cache["paged"]
    if spec.cross:
        k, v = aux_kv                                  # precomputed, cached
        mask = None
    else:
        k = (x @ params["wk"].astype(CDT)).reshape(B, S, hkv, dh)
        v = (x @ params["wv"].astype(CDT)).reshape(B, S, hkv, dh)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if paged is not None:
            paged.append(k.astype(CDT), v.astype(CDT))
            out = paged.attend(q, cfg.softcap_attn)
            out = constrain(out, "batch", None, "heads", None)
            out = out.reshape(B, S, hq * dh)
            if pim_ctx is not None and "attn_o" in pim_ctx.targets:
                y = pim_ctx.matmul(out, params["wo"], "attn_o",
                                   enc=params.get("wo_enc"),
                                   alpha=params.get("wo_alpha"))
            else:
                y = out @ params["wo"].astype(CDT)
            return constrain(y, "batch", None, None), None
        if kv_cache is not None:
            # single-token decode: scatter into the cache. Sliding-window
            # layers allocate the cache as a ring of size W = local_window and
            # write at (pos % W); K carries *absolute* RoPE so relative
            # offsets survive the wrap.
            W = kv_cache["k"].shape[1]
            slot = cache_pos % W if W < 2 ** 31 else cache_pos
            ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k.astype(CDT),
                                                     slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v.astype(CDT),
                                                     slot, axis=1)
            ck = constrain(ck, "batch", "kv_seq", "kv_heads", None)
            cv = constrain(cv, "batch", "kv_seq", "kv_heads", None)
            new_cache = {"k": ck, "v": cv}
            k, v = ck, cv
            kv_pos = jnp.arange(W)
            ok = kv_pos[None, :] <= cache_pos          # ring full => all True
            if spec.local_window and spec.local_window < W:
                ok &= kv_pos[None, :] > cache_pos - spec.local_window
            mask = ok[:, None, :][None]                # (1,1,1,Skv) -> bcast
            mask = jnp.broadcast_to(mask, (B, 1, S, W))
        else:
            qpos = jnp.arange(S)
            kpos = jnp.arange(S)
            ok = kpos[None, :] <= qpos[:, None]
            if spec.local_window:
                ok &= kpos[None, :] > qpos[:, None] - spec.local_window
            if not getattr(spec, "causal", True):
                ok = jnp.ones((S, S), bool)
            mask = ok[None, None]
    if spec.cross:
        mask = None                                     # full visibility of aux

    k = constrain(k, "batch", "kv_seq", "kv_heads", None)
    v = constrain(v, "batch", "kv_seq", "kv_heads", None)
    impl = cfg.attn_impl if (kv_cache is None) else "naive"
    out = _attend(q, k, v, mask, cfg.softcap_attn, impl=impl,
                  causal=(not spec.cross) and mask is not None,
                  window=spec.local_window)
    out = constrain(out, "batch", None, "heads", None)
    out = out.reshape(B, S, hq * dh)
    if pim_ctx is not None and "attn_o" in pim_ctx.targets:
        y = pim_ctx.matmul(out, params["wo"], "attn_o",
                           enc=params.get("wo_enc"),
                           alpha=params.get("wo_alpha"))
    else:
        y = out @ params["wo"].astype(CDT)
    return constrain(y, "batch", None, None), new_cache


def encoder_attention_apply(params, x, cfg: ArchConfig, positions):
    """Bidirectional self-attention (whisper encoder)."""
    spec = LayerSpec(kind="attn")
    B, S, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"].astype(CDT)).reshape(B, S, hq, dh)
    k = (x @ params["wk"].astype(CDT)).reshape(B, S, hkv, dh)
    v = (x @ params["wv"].astype(CDT)).reshape(B, S, hkv, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = _attend(q, k, v, None, cfg.softcap_attn, impl=cfg.attn_impl,
                  causal=False)
    return (out.reshape(B, S, hq * dh) @ params["wo"].astype(CDT))
