"""NN substrate: attention/MLP/norm layers, MoE, Mamba blocks."""
from .kv_source import KVSource
from .layers import (rmsnorm, rope, init_mlp, mlp_apply, init_attention,
                     attention_apply, encoder_attention_apply, CDT)
from .moe import init_moe, moe_apply, moe_dense, moe_sorted_ep
from .mamba import (init_mamba, mamba_apply, init_mamba_state, MambaState,
                    mamba_param_axes)
