"""KVSource — the decode-time KV provider protocol behind `attention_apply`.

Decode-time attention used to route on cache *shape*: a plain
``{"k", "v"}`` dict meant a dense ring buffer, while the magic
``{"paged": layer}`` dict smuggled a protected paged layer through the same
argument. That string-keyed routing is replaced by this protocol: anything
that can append a step's K/V and be attended over implements `KVSource`,
and `attention_apply` dispatches on `isinstance` instead of dict keys.

Implementations in-tree:

- `repro.models.kv.ProtectedKVLayer` — single-tenant protected paged K/V
  (kind "protected"); its `attend` takes the fused one-kernel path when
  `ProtectedKVConfig.fused` and falls back to the streaming per-page
  online-softmax otherwise.
- `repro.serving.engine.BatchedPagedKV` — the multi-tenant engine's
  per-slot pool-backed pages (kind "protected").
- `repro.serving.engine.BatchedDenseKV` — the engine's unprotected dense
  baseline (kind "dense"), served through the default streaming attend.

The default `attend` streams `pages()` through the page-granular
online-softmax (`repro.nn.layers._attend_paged`), so a minimal source only
has to provide `append` and `pages`; fused implementations override
`attend` and keep `pages()` as the exact-parity reference path
(tests/test_fused_attention.py asserts the two agree bitwise).
"""
from __future__ import annotations

import abc


class KVSource(abc.ABC):
    """A decode-time KV provider `attention_apply` can attend over."""

    #: coarse provenance tag ("dense" | "protected") for logging/stats
    kind: str = "dense"

    @abc.abstractmethod
    def append(self, k, v) -> None:
        """Ingest one step's (B, t, Hkv, D) K/V (RoPE already applied)."""

    @abc.abstractmethod
    def pages(self):
        """Yield (k_page (B, T, Hkv, D), v_page, valid_tokens) steps for
        the streaming online-softmax — the reference read path every
        implementation keeps, fused or not."""

    def attend(self, q, softcap=0.0):
        """(B, Sq, Hq, D) query block -> attention output over this
        source's K/V. Default: stream `pages()` through the page-granular
        online-softmax; fused sources override."""
        from .layers import _attend_paged
        return _attend_paged(q, self.pages(), softcap)
