"""Pallas TPU kernel: GF(p) matrix multiply (encode / syndrome).

`w · H_G` (encode, paper Fig. 2(b)) and `Y' · H_Cᵀ` (syndrome, paper Eq. 3/5)
are integer matmuls with a mod-p epilogue. The ASIC uses mux-based sparse
routing; the TPU-idiomatic equivalent is a dense MXU matmul tiled 128×128 with
the mod fused into the final K-step (DESIGN.md §3).

Accumulation is exact int32; inputs are small integers (field symbols or
centered lifts), far from overflow for K ≤ 2^20.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gf_matmul_kernel(a_ref, b_ref, o_ref, *, p: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] % p


def gf_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, p: int, *,
                     bm: int = 128, bn: int = 128, bk: int = 128,
                     interpret: bool = True) -> jnp.ndarray:
    """(a @ b) % p. a: (M, K) int, b: (K, N) int -> (M, N) int32.

    The output block is revisited across the K grid dimension (accumulate in
    VMEM, mod-p epilogue on the last step). Caller (`ops.gf_matmul`) pads
    M/N/K to block multiples.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    kern = functools.partial(_gf_matmul_kernel, p=p, nk=nk)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        grid=(M // bm, N // bn, nk),
        interpret=interpret,
    )(a, b)
