"""Pallas TPU kernels: GF(p) matrix multiply (encode / syndrome) and the
fused scrub syndrome scan.

`w · H_G` (encode, paper Fig. 2(b)) and `Y' · H_Cᵀ` (syndrome, paper Eq. 3/5)
are integer matmuls with a mod-p epilogue. The ASIC uses mux-based sparse
routing; the TPU-idiomatic equivalent is a dense MXU matmul tiled 128×128 with
the mod fused into the final K-step (DESIGN.md §3).

`scan_syndromes_pallas` is the memory-mode scrub hot path (`H·yᵀ mod p` over
every stored word, paper §3 / ROADMAP "Pallas scrub kernel"): the same
K-blocked MXU accumulation, but the mod-p + nonzero-any reduction over the
check dimension is fused into the last K-step, so only a (B,) flagged mask
leaves the kernel — the full syndrome matrix never exists outside VMEM.

Accumulation is exact int32; inputs are small integers (field symbols or
centered lifts), far from overflow for K ≤ 2^20.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import resolve_interpret

# lane width of the flag output block: flags are per-row scalars, but TPU
# blocks need a 128-wide minor dim; the wrapper slices column 0.
FLAG_LANES = 128


def _gf_matmul_kernel(a_ref, b_ref, o_ref, *, p: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] % p


def gf_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, p: int, *,
                     bm: int = 128, bn: int = 128, bk: int = 128,
                     interpret: bool | None = None) -> jnp.ndarray:
    """(a @ b) % p. a: (M, K) int, b: (K, N) int -> (M, N) int32.

    The output block is revisited across the K grid dimension (accumulate in
    VMEM, mod-p epilogue on the last step). Caller (`ops.gf_matmul`) pads
    M/N/K to block multiples.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    kern = functools.partial(_gf_matmul_kernel, p=p, nk=nk)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        grid=(M // bm, N // bn, nk),
        interpret=resolve_interpret(interpret),
    )(a, b)


def _scan_syndromes_kernel(y_ref, ht_ref, o_ref, acc_ref, *, p: int, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    y = y_ref[...].astype(jnp.int32)
    ht = ht_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        y, ht, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _flag():
        nz = ((acc_ref[...] % p) != 0).astype(jnp.int32)
        o_ref[...] = jnp.broadcast_to(
            jnp.max(nz, axis=1, keepdims=True), o_ref.shape)


def scan_syndromes_pallas(y: jnp.ndarray, ht: jnp.ndarray, p: int, *,
                          bm: int = 128, bk: int = 128,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Fused scrub scan: flags[i] = any((y[i] @ ht) % p != 0).

    y: (M, K) stored level-words, ht: (K, C) check matrix transpose ->
    (M, FLAG_LANES) int32 with the per-word flag broadcast across lanes
    (callers read column 0). The (bm, C) syndrome accumulator lives in VMEM
    scratch and is reduced in the last K-step — the syndrome matrix is never
    written to HBM. Caller (`ops.scan_syndromes`) pads M/K to block multiples
    and C to a lane multiple.
    """
    M, K = y.shape
    K2, C = ht.shape
    assert K == K2
    assert M % bm == 0 and K % bk == 0 and C % FLAG_LANES == 0
    nk = K // bk
    kern = functools.partial(_scan_syndromes_kernel, p=p, nk=nk)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((M, FLAG_LANES), jnp.int32),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, C), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, FLAG_LANES), lambda i, k: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bm, C), jnp.int32)],
        grid=(M // bm, nk),
        interpret=resolve_interpret(interpret),
    )(y, ht)
