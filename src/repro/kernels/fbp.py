"""Pallas TPU kernel: check-node Forward-Backward Propagation (paper §3.2.2).

The ASIC runs one CN serially over its D_C incident LLV groups; the TPU analogue
batches thousands of independent (codeword × CN) FBP problems across VPU lanes.

Layout: messages (N, dc, p) float32 in contribution space. We tile N into VMEM
blocks; dc and p are small compile-time constants, so the FM/BM chains fully
unroll into vector ops over whole (tile_n, p) blocks.

The cyclic max-plus convolution over the GF axis is expressed as p static
rolls of the (tile_n, p) block (each roll is a concat of two static slices —
cheap lane shuffles on the VPU) followed by a broadcast add and running max,
so every instruction operates on a full tile instead of p separate
(tile_n,) vectors.

The chain over dc is inherently serial (it IS the algorithm, paper Fig. 3(c));
parallelism comes from the batch dimension, mirroring the paper's N_VI-way VN
array feeding one shared CN.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.llv import NEG_INF

from .backend import resolve_interpret

DEFAULT_TILE_N = 512


def _roll_gf(a, j: int, p: int):
    """roll(a, j) along the last (GF) axis with a static shift:
    out[:, k] = a[:, (k - j) % p]."""
    j = j % p
    if j == 0:
        return a
    return jnp.concatenate([a[:, p - j:], a[:, :p - j]], axis=-1)


def _conv_block(a, b, p: int):
    """Cyclic max-plus convolution on whole (tile_n, p) blocks:
    out[:, k] = max_j a[:, (k - j) % p] + b[:, j]."""
    acc = a + b[:, 0:1]                       # j = 0 term
    for j in range(1, p):
        acc = jnp.maximum(acc, _roll_gf(a, j, p) + b[:, j:j + 1])
    return acc


def _reflect_block(x, p: int):
    """out[:, k] = x[:, (-k) % p] — keep element 0, reverse elements 1..p-1."""
    if p == 1:
        return x
    return jnp.concatenate([x[:, :1], jnp.flip(x[:, 1:], axis=-1)], axis=-1)


def _fbp_kernel(m_ref, o_ref, *, dc: int, p: int):
    # m_ref/o_ref: (tile_n, dc, p) VMEM blocks; slot messages are whole
    # (tile_n, p) tiles
    msgs = [m_ref[:, t, :] for t in range(dc)]

    fm = [msgs[0]]
    for t in range(1, dc):
        fm.append(_conv_block(fm[-1], msgs[t], p))
    bm_rev = [msgs[dc - 1]]
    for t in range(dc - 2, -1, -1):
        bm_rev.append(_conv_block(msgs[t], bm_rev[-1], p))
    bm = bm_rev[::-1]                      # bm[t] = conv of slots t..dc-1

    if dc == 1:
        col = jax.lax.broadcasted_iota(jnp.int32, (m_ref.shape[0], p), 1)
        ident = jnp.where(col == 0, jnp.zeros((), m_ref.dtype),
                          jnp.full((), NEG_INF, m_ref.dtype))

    for t in range(dc):
        if t == 0:
            ext = bm[1] if dc > 1 else ident
        elif t == dc - 1:
            ext = fm[dc - 2]
        else:
            ext = _conv_block(fm[t - 1], bm[t + 1], p)
        # reflect: out[:, k] = ext[:, (-k) % p] (sum of others must equal -u_t)
        o_ref[:, t, :] = _reflect_block(ext, p)


def fbp_cn_pallas(m_hat: jnp.ndarray, p: int, *, tile_n: int = DEFAULT_TILE_N,
                  interpret: bool | None = None) -> jnp.ndarray:
    """m_hat: (N, dc, p) -> reflected extrinsic messages (N, dc, p).

    N is padded to a tile multiple by the caller (`ops.fbp_cn`). `interpret`
    defaults to the shared backend dispatch (compiled on TPU, interpreted
    elsewhere) so direct callers match `ops.fbp_cn`.
    """
    N, dc, pp = m_hat.shape
    assert pp == p
    assert N % tile_n == 0, f"N={N} not a multiple of tile_n={tile_n}"
    kern = functools.partial(_fbp_kernel, dc=dc, p=p)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((N, dc, p), m_hat.dtype),
        in_specs=[pl.BlockSpec((tile_n, dc, p), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_n, dc, p), lambda i: (i, 0, 0)),
        grid=(N // tile_n,),
        interpret=resolve_interpret(interpret),
    )(m_hat)
