"""Pallas TPU kernel: check-node Forward-Backward Propagation (paper §3.2.2).

The ASIC runs one CN serially over its D_C incident LLV groups; the TPU analogue
batches thousands of independent (codeword × CN) FBP problems across VPU lanes.

Layout: messages (N, dc, p) float32 in contribution space. We tile N into VMEM
blocks; dc and p are small compile-time constants, so the FM/BM chains and the
cyclic max-plus convolutions fully unroll into vector ops over the N-tile.

The chain over dc is inherently serial (it IS the algorithm, paper Fig. 3(c));
parallelism comes from the batch dimension, mirroring the paper's N_VI-way VN
array feeding one shared CN.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.llv import NEG_INF

DEFAULT_TILE_N = 512


def _conv(a, b, p):
    """Cyclic max-plus convolution; a, b: tuples of p vectors (tile_n,)."""
    out = []
    for k in range(p):
        acc = None
        for j in range(p):
            s = a[(k - j) % p] + b[j]
            acc = s if acc is None else jnp.maximum(acc, s)
        out.append(acc)
    return tuple(out)


def _fbp_kernel(m_ref, o_ref, *, dc: int, p: int):
    # m_ref/o_ref: (tile_n, dc, p) VMEM blocks
    msgs = [tuple(m_ref[:, t, k] for k in range(p)) for t in range(dc)]

    fm = [msgs[0]]
    for t in range(1, dc):
        fm.append(_conv(fm[-1], msgs[t], p))
    bm_rev = [msgs[dc - 1]]
    for t in range(dc - 2, -1, -1):
        bm_rev.append(_conv(msgs[t], bm_rev[-1], p))
    bm = bm_rev[::-1]                      # bm[t] = conv of slots t..dc-1

    shape = m_ref.shape[0:1]
    ident = tuple(
        jnp.zeros(shape, m_ref.dtype) if k == 0
        else jnp.full(shape, NEG_INF, m_ref.dtype)
        for k in range(p))

    for t in range(dc):
        if t == 0:
            ext = bm[1] if dc > 1 else ident
        elif t == dc - 1:
            ext = fm[dc - 2]
        else:
            ext = _conv(fm[t - 1], bm[t + 1], p)
        # reflect: out[k] = ext[(-k) % p]   (sum of others must equal -u_t)
        for k in range(p):
            o_ref[:, t, k] = ext[(-k) % p]


def fbp_cn_pallas(m_hat: jnp.ndarray, p: int, *, tile_n: int = DEFAULT_TILE_N,
                  interpret: bool = True) -> jnp.ndarray:
    """m_hat: (N, dc, p) -> reflected extrinsic messages (N, dc, p).

    N is padded to a tile multiple by the caller (`ops.fbp_cn`).
    """
    N, dc, pp = m_hat.shape
    assert pp == p
    assert N % tile_n == 0, f"N={N} not a multiple of tile_n={tile_n}"
    kern = functools.partial(_fbp_kernel, dc=dc, p=p)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((N, dc, p), m_hat.dtype),
        in_specs=[pl.BlockSpec((tile_n, dc, p), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_n, dc, p), lambda i: (i, 0, 0)),
        grid=(N // tile_n,),
        interpret=interpret,
    )(m_hat)
