"""Jitted public wrappers for the Pallas kernels.

Handle padding to tile multiples, backend dispatch via
`repro.kernels.backend.KernelPolicy` (compiled on TPU, interpret/ref
elsewhere — override with `use_policy`), and expose drop-in callables for
the core library:
  - fbp_cn           : plugs into repro.core.decode.decode_llv(cn_fbp=...)
  - gf_matmul        : encode / syndrome matmuls
  - pim_mac          : quantized-MAC forward
  - attend_protected : fused GF-page paged attention (the serving hot path)

Each wrapper resolves its backend OUTSIDE the jit boundary (the inner
jitted impls take the resolved `interpret` flag as a static arg), so a
`with use_policy(...)` override always selects the right executable
instead of hitting a trace cached under an earlier policy. The per-call
`interpret: bool | None` keyword is retained as a low-level escape hatch;
prefer `KernelPolicy` / `use_policy` for mode selection.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import fbp as _fbp
from . import gf_matmul as _gfm
from . import pim_mac as _pm
from .backend import resolve_interpret as _resolve_interpret
from .backend import resolve_mode as _resolve_mode
from repro.analysis.sanitizer import (check_finite, check_gf_symbols,
                                      check_quant_scales, sanitizer_enabled)
from repro.core.llv import NEG_INF


def _pad_to(x, axis, multiple, value=0):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


@functools.partial(jax.jit, static_argnames=("p", "tile_n", "interpret"))
def _fbp_cn_jit(m_hat: jnp.ndarray, p: int, tile_n: int,
                interpret: bool) -> jnp.ndarray:
    N = m_hat.shape[0]
    # pick the tile first, then derive the pad FROM the chosen tile so the
    # padded batch is a tile multiple by construction (asserted below; the
    # 8-row floor matches the float32 sublane minimum, so a smaller explicit
    # tile_n is rounded up rather than honored)
    tile = max(8, min(tile_n, N))
    padded, pad = _pad_to(m_hat, 0, tile)
    assert padded.shape[0] % tile == 0, (
        f"padded N={padded.shape[0]} not divisible by tile={tile}")
    if pad:  # padded rows: identity messages (harmless)
        fill = jnp.full((pad,) + m_hat.shape[1:], NEG_INF, m_hat.dtype)
        fill = fill.at[..., 0].set(0.0)
        padded = padded.at[N:].set(fill)
    out = _fbp.fbp_cn_pallas(padded, p, tile_n=tile, interpret=interpret)
    return out[:N]


def fbp_cn(m_hat: jnp.ndarray, p: int, *, tile_n: int = _fbp.DEFAULT_TILE_N,
           interpret: bool | None = None) -> jnp.ndarray:
    """(N, dc, p) contribution-space messages -> reflected extrinsics."""
    return _fbp_cn_jit(m_hat, p, tile_n, _resolve_interpret(interpret))


def fbp_cn_batched(m_hat: jnp.ndarray, p: int, **kw) -> jnp.ndarray:
    """Adapter matching decode_llv's cn_fbp signature: (B, c, dc, p)."""
    B, c, dc, pp = m_hat.shape
    out = fbp_cn(m_hat.reshape(B * c, dc, pp), p, **kw)
    return out.reshape(B, c, dc, pp)


@functools.partial(jax.jit, static_argnames=("p", "bm", "bn", "bk",
                                             "interpret"))
def _gf_matmul_jit(a: jnp.ndarray, b: jnp.ndarray, p: int, bm: int, bn: int,
                   bk: int, interpret: bool) -> jnp.ndarray:
    M, K = a.shape
    _, N = b.shape
    # same int32 accumulator as scan_syndromes: every dot-product term is a
    # product of two symbols in [0, p), so K*(p-1)^2 must stay below 2^31
    # or the mod-p epilogue sees a wrapped sum and returns garbage
    assert K * (p - 1) ** 2 < 2 ** 31, (
        f"gf_matmul int32 bound exceeded: {K} * ({p}-1)^2 >= 2^31")
    bm_, bn_, bk_ = (min(bm, max(8, M)), min(bn, max(8, N)), min(bk, max(8, K)))
    a, _ = _pad_to(a, 0, bm_)
    a, _ = _pad_to(a, 1, bk_)
    b, _ = _pad_to(b, 0, bk_)
    b, _ = _pad_to(b, 1, bn_)
    out = _gfm.gf_matmul_pallas(a, b, p, bm=bm_, bn=bn_, bk=bk_,
                                interpret=interpret)
    return out[:M, :N]


def gf_matmul(a: jnp.ndarray, b: jnp.ndarray, p: int, *, bm: int = 128,
              bn: int = 128, bk: int = 128,
              interpret: bool | None = None) -> jnp.ndarray:
    """(a @ b) % p with padding to MXU-aligned blocks."""
    if sanitizer_enabled():
        check_gf_symbols(a, p, "gf_matmul lhs")
        check_gf_symbols(b, p, "gf_matmul rhs")
    return _gf_matmul_jit(a, b, p, bm, bn, bk, _resolve_interpret(interpret))


def encode_words(u: jnp.ndarray, P: jnp.ndarray, p: int, *, bm: int = 128,
                 bn: int = 128, bk: int = 128,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Device-side systematic encode: (B, k) info symbols in [0, p) and the
    code's (k, c) check generator -> (B, k + c) codewords [u | (u·P) mod p].

    The check matmul runs through the Pallas `gf_matmul` MXU path (mod-p
    fused into the last K-step), so encoding a page of words never leaves
    the device — this is the write hot path of
    `repro.memory.paged.PagedProtectedStore`. Bit-exact against the host
    `repro.core.np_encode_words` (`kernels.ref.encode_words_ref` is the
    tested oracle).
    """
    checks = gf_matmul(u, P, p, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return jnp.concatenate([u.astype(jnp.int32), checks], axis=-1)


@functools.partial(jax.jit, static_argnames=("p", "bm", "bk", "interpret"))
def _scan_syndromes_jit(y: jnp.ndarray, ht: jnp.ndarray, p: int, bm: int,
                        bk: int, interpret: bool) -> jnp.ndarray:
    M, K = y.shape
    _, C = ht.shape
    # the kernel accumulator is int32: every syndrome sum is bounded by
    # K*(p-1)^2, which must stay below 2^31 or flags silently wrap. The
    # controller routes such codes to its exact int64 host path.
    assert K * (p - 1) ** 2 < 2 ** 31, (
        f"scan_syndromes int32 bound exceeded: {K} * ({p}-1)^2 >= 2^31")
    bm_, bk_ = min(bm, max(8, M)), min(bk, max(8, K))
    y, _ = _pad_to(y, 0, bm_)
    y, _ = _pad_to(y, 1, bk_)
    ht, _ = _pad_to(ht, 0, bk_)
    ht, _ = _pad_to(ht, 1, _gfm.FLAG_LANES)
    out = _gfm.scan_syndromes_pallas(y, ht, p, bm=bm_, bk=bk_,
                                     interpret=interpret)
    return out[:M, 0] != 0


def scan_syndromes(y: jnp.ndarray, ht: jnp.ndarray, p: int, *, bm: int = 128,
                   bk: int = 128,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Fused scrub syndrome scan: (B, n) words x (n, c) Hᵀ -> (B,) bool flags.

    flags[i] = any((y[i] @ ht) % p != 0); the mod + any reduction is fused
    into the matmul's last K-step so only the mask leaves the kernel. Pad
    rows (zero words are valid codewords) and pad check columns (all-zero
    Hᵀ columns accumulate 0 ≡ 0 mod p) can never raise a flag.
    """
    if sanitizer_enabled():
        check_gf_symbols(y, p, "scan_syndromes words")
    return _scan_syndromes_jit(y, ht, p, bm, bk, _resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("row_parallelism", "adc_levels",
                                             "bm", "bn", "interpret"))
def _pim_mac_jit(x: jnp.ndarray, w: jnp.ndarray, row_parallelism: int,
                 adc_levels: int, bm: int, bn: int,
                 interpret: bool) -> jnp.ndarray:
    B, K = x.shape
    _, N = w.shape
    R = row_parallelism if row_parallelism > 0 else K
    bm_, bn_ = min(bm, max(8, B)), min(bn, max(8, N))
    x, _ = _pad_to(x, 0, bm_)
    x, _ = _pad_to(x, 1, R)           # zero rows contribute clip(0)=0
    w, _ = _pad_to(w, 0, R)
    w, _ = _pad_to(w, 1, bn_)
    out = _pm.pim_mac_pallas(x, w, row_parallelism=R, adc_levels=adc_levels,
                             bm=bm_, bn=bn_, interpret=interpret)
    return out[:B, :N]


def pim_mac(x: jnp.ndarray, w: jnp.ndarray, *, row_parallelism: int = 0,
            adc_levels: int = 0, bm: int = 128, bn: int = 128,
            interpret: bool | None = None) -> jnp.ndarray:
    """Row-group-quantized MAC (B, K) x (K, N) -> (B, N) int32."""
    return _pim_mac_jit(x, w, row_parallelism, adc_levels, bm, bn,
                        _resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# fused protected paged attention (the one-kernel serving hot path)
# ---------------------------------------------------------------------------


def np_bucket(n: int) -> int:
    """Page-count bucket: next power of two (min 1). The fused executable's
    shapes include the page axis, so serving pads the page stack to a
    bucket with zero pages (valid codewords, scale 0, valid 0 — exact
    no-ops in the online-softmax recurrence) and one trace serves a whole
    range of sequence lengths instead of retracing on every page freeze."""
    return 1 if n <= 1 else 2 ** math.ceil(math.log2(n))


@functools.partial(jax.jit, static_argnames=("p", "k_info", "page_shape",
                                             "softcap", "with_hot"))
def _attend_protected_ref_jit(q, kpages, vpages, kscales, vscales, valid,
                              hot_k, hot_v, hot_valid, *, p, k_info,
                              page_shape, softcap, with_hot):
    from .ref import attend_protected_ref
    return attend_protected_ref(
        q, kpages, vpages, kscales, vscales, valid, hot_k, hot_v, hot_valid,
        p=p, k_info=k_info, page_shape=page_shape, softcap=softcap,
        with_hot=with_hot)


@functools.partial(jax.jit, static_argnames=("p", "k_info", "page_shape",
                                             "softcap", "with_hot",
                                             "interpret"))
def _attend_protected_kernel_jit(q, kpages, vpages, kscales, vscales, valid,
                                 hot_k, hot_v, hot_valid, *, p, k_info,
                                 page_shape, softcap, with_hot, interpret):
    from . import paged_attention as _pa
    return _pa.attend_protected_pallas(
        q, kpages, vpages, kscales, vscales, valid, hot_k, hot_v, hot_valid,
        p=p, k_info=k_info, page_shape=page_shape, softcap=softcap,
        with_hot=with_hot, interpret=interpret)


def attend_protected(q, kpages, vpages, kscales, vscales, valid,
                     hot_k, hot_v, hot_valid, *, p: int, k_info: int,
                     page_shape, softcap: float = 0.0, with_hot: bool = True,
                     policy=None):
    """Fused protected paged attention: corrected GF pages + quantization
    scales + query block -> attention output in one executable.

    q: (B, Sq, Hq, D). kpages/vpages: (NP, S, W, n) int32 corrected GF
    pages — page step j is S sub-pages of `page_shape` = (Bsub, T, Hkv, D)
    stacked along batch (S·Bsub = B). kscales/vscales: (NP, S) f32 absmax
    scales; valid: (NP, B) int32 per-step per-row valid token counts.
    hot_k/hot_v: (B, T, Hkv, D) dense hot page applied last when
    `with_hot`, filled to hot_valid (B,).

    Dispatch follows `policy` (default: the ambient `KernelPolicy`): the
    jnp oracle graph in ref mode — bit-exact vs the unfused streaming path
    (`repro.nn.layers._attend_paged`) by shared-recurrence construction —
    or the Pallas kernel (`kernels/paged_attention.py`, fp32 in-VMEM math,
    allclose parity) compiled / interpreted otherwise. The page axis is
    padded to `np_bucket(NP)` with no-op zero pages so one trace serves a
    range of page counts.
    """
    NP = kpages.shape[0]
    B = q.shape[0]
    valid = jnp.asarray(valid, jnp.int32).reshape(max(NP, 0), B)
    hot_valid = jnp.asarray(hot_valid, jnp.int32).reshape(B)
    NB = np_bucket(NP)
    if NB != NP:
        pad = NB - NP

        def zpad(x):
            return jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]) if NP else \
                jnp.zeros((pad,) + x.shape[1:], x.dtype)

        kpages, vpages = zpad(kpages), zpad(vpages)
        kscales, vscales = zpad(kscales), zpad(vscales)
        valid = zpad(valid)
    kw = dict(p=int(p), k_info=int(k_info), page_shape=tuple(page_shape),
              softcap=float(softcap or 0.0), with_hot=bool(with_hot))
    if sanitizer_enabled():
        check_gf_symbols(kpages, p, "attend_protected K pages")
        check_gf_symbols(vpages, p, "attend_protected V pages")
        check_quant_scales(kscales, "attend_protected K scales")
        check_quant_scales(vscales, "attend_protected V scales")
        check_finite(q, "attend_protected query")
    mode = _resolve_mode(policy)
    if mode == "ref":
        out = _attend_protected_ref_jit(
            q, kpages, vpages, kscales, vscales, valid, hot_k, hot_v,
            hot_valid, **kw)
    else:
        out = _attend_protected_kernel_jit(
            q, kpages, vpages, kscales, vscales, valid, hot_k, hot_v,
            hot_valid, interpret=(mode != "compiled"), **kw)
    if sanitizer_enabled():
        # a NaN that slipped into K/V/hot poisons the online-softmax
        # m/l/acc recurrence without raising — surface it here
        check_finite(out, "attend_protected output")
    return out


# ---------------------------------------------------------------------------
# flash attention (fwd + bwd Pallas kernels, custom_vjp)
# ---------------------------------------------------------------------------

from . import flash_attention as _fa


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=0, softcap=0.0,
                    scale=None, interpret=None):
    """Flash attention with GQA. q: (B,Sq,Hq,D); k/v: (B,Skv,Hkv,D).
    Returns (B,Sq,Hq,D) in q.dtype. O(S*D) HBM traffic (see kernel docs)."""
    o, _ = _flash_fwd_rule(q, k, v, causal, window, softcap, scale, interpret)
    return o


def _fold(x):
    B, S, H, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)


def _unfold(x, B, H):
    BH, S, D = x.shape
    return x.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def _pad_seq(x, mult):
    S = x.shape[1]
    pad = (-S) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x, S


def _flash_fwd_rule(q, k, v, causal, window, softcap, scale, interpret):
    interpret = _resolve_interpret(interpret)
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    sc = scale if scale is not None else 1.0 / (D ** 0.5)
    bq = min(_fa.DEFAULT_BLOCK_Q, Sq)
    bk = min(_fa.DEFAULT_BLOCK_KV, Skv)
    qp, _ = _pad_seq(q, bq)
    kp, _ = _pad_seq(k, bk)
    vp, _ = _pad_seq(v, bk)
    kv_len = Skv if kp.shape[1] != Skv else 0
    o2, lse = _fa.flash_fwd(_fold(qp), _fold(kp), _fold(vp), g=g, scale=sc,
                            causal=causal, window=window, softcap=softcap,
                            bq=bq, bk=bk, kv_len=kv_len, interpret=interpret)
    o = _unfold(o2, B, Hq)[:, :Sq]
    return o, (q, k, v, o, lse[:, :Sq])


def _flash_bwd_rule(causal, window, softcap, scale, interpret, res, do):
    q, k, v, o, lse = res
    interpret = _resolve_interpret(interpret)
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    sc = scale if scale is not None else 1.0 / (D ** 0.5)
    bq = min(_fa.DEFAULT_BLOCK_Q, Sq)
    bk = min(_fa.DEFAULT_BLOCK_KV, Skv)
    qp, _ = _pad_seq(q, bq)
    kp, _ = _pad_seq(k, bk)
    vp, _ = _pad_seq(v, bk)
    op, _ = _pad_seq(o, bq)
    dop, _ = _pad_seq(do, bq)
    kv_len = Skv if kp.shape[1] != Skv else 0
    Sqp = qp.shape[1]
    lsep = lse
    if Sqp != Sq:
        lsep = jnp.pad(lse, ((0, 0), (0, Sqp - Sq)))
    dq2, dk2, dv2 = _fa.flash_bwd(_fold(qp), _fold(kp), _fold(vp), _fold(op),
                                  lsep, _fold(dop), g=g, scale=sc,
                                  causal=causal, window=window,
                                  softcap=softcap, bq=bq, bk=bk,
                                  kv_len=kv_len, interpret=interpret)
    Skvp = kp.shape[1]
    dq = _unfold(dq2, B, Hq)[:, :Sq]
    # dk/dv are per-q-head: sum each GQA group back to its kv head
    dk = _unfold(dk2, B, Hq)[:, :Skv].reshape(B, Skv, Hkv, g, D).sum(3)
    dv = _unfold(dv2, B, Hq)[:, :Skv].reshape(B, Skv, Hkv, g, D).sum(3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)
