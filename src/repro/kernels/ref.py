"""Pure-jnp oracles for the Pallas kernels.

Each function is the semantic ground truth its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.decode import _cn_fbp_jnp, maxplus_conv  # noqa: F401


def fbp_cn_ref(m_hat: jnp.ndarray, p: int) -> jnp.ndarray:
    """m_hat: (N, dc, p) contribution-space messages (padded slots already hold
    the max-plus identity). Returns reflected extrinsic messages (N, dc, p)."""
    # _cn_fbp_jnp expects (B, c, dc, p); fold N into (N, 1, dc, p)
    out = _cn_fbp_jnp(m_hat[:, None], p)
    return out[:, 0]


def gf_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, p: int) -> jnp.ndarray:
    """(a @ b) mod p with exact int32 accumulation. a: (M, K), b: (K, N)."""
    return (a.astype(jnp.int32) @ b.astype(jnp.int32)) % p


def scan_syndromes_ref(y: jnp.ndarray, ht: jnp.ndarray, p: int) -> jnp.ndarray:
    """Unfused scrub-scan oracle: full syndrome matrix, then the any-reduce."""
    return (gf_matmul_ref(y, ht, p) != 0).any(axis=1)


def encode_words_ref(u: jnp.ndarray, P: jnp.ndarray, p: int) -> jnp.ndarray:
    """Systematic-encode oracle: [u | (u @ P) mod p], exact int32
    accumulation. u: (B, k) info symbols in [0, p); P: (k, c)."""
    return jnp.concatenate([u.astype(jnp.int32), gf_matmul_ref(u, P, p)],
                           axis=-1)


def pim_mac_ref(x: jnp.ndarray, w: jnp.ndarray, *, row_parallelism: int,
                adc_levels: int) -> jnp.ndarray:
    """Row-grouped ADC-quantized MAC. x: (B, K), w: (K, N); K divisible by the
    row-parallelism R. Partial sums of each R-row group are clipped to the ADC
    range before digital accumulation."""
    B, K = x.shape
    R = row_parallelism if row_parallelism > 0 else K
    assert K % R == 0
    g = K // R
    xg = x.astype(jnp.int32).reshape(B, g, R)
    wg = w.astype(jnp.int32).reshape(g, R, w.shape[1])
    partial = jnp.einsum("bgr,gro->bgo", xg, wg)
    if adc_levels > 0:
        half = adc_levels // 2
        partial = jnp.clip(partial, -half, half)
    return partial.sum(axis=1)


def paged_softmax_update(q, kpg, vpg, valid, m, l, acc, softcap=0.0):
    """One online-softmax step over a decoded KV page — THE page-granular
    flash-attention recurrence. This is the single source of truth shared
    by the streaming reference path (`repro.nn.layers._paged_attn_update`
    jits exactly this) and the fused `attend_protected_ref` oracle, so the
    two paths are bit-identical by construction.

    q: (B,Sq,Hq,D); kpg/vpg: (B,T,Hkv,D); valid: () or (B,) int32 tokens of
    the page that are real per sequence. Carries (m, l, acc) in fp32 with
    shapes (B,Hkv,G,Sq,1) / (B,Hkv,G,Sq,1) / (B,Hkv,G,Sq,D)."""
    B, Sq, Hq, D = q.shape
    T, Hkv = kpg.shape[1], kpg.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kpg).astype(jnp.float32)
    logits = logits / jnp.sqrt(D).astype(jnp.float32)
    if softcap and softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    ok = (jnp.arange(T)[None, None, None, None, :]
          < jnp.reshape(valid, (-1, 1, 1, 1, 1)))
    logits = jnp.where(ok, logits, -1e30)
    pm = logits.max(axis=-1, keepdims=True)          # (B,Hkv,G,Sq,1)
    new_m = jnp.maximum(m, pm)
    w = jnp.exp(logits - new_m)
    corr = jnp.exp(m - new_m)
    new_l = corr * l + w.sum(axis=-1, keepdims=True)
    new_acc = corr * acc + jnp.einsum(
        "bhgqk,bkhd->bhgqd", w, vpg.astype(jnp.float32))
    return new_m, new_l, new_acc


def paged_softmax_init(B, Hkv, G, Sq, D):
    """Fresh (m, l, acc) carries for the paged recurrence."""
    return (jnp.full((B, Hkv, G, Sq, 1), -jnp.inf, jnp.float32),
            jnp.zeros((B, Hkv, G, Sq, 1), jnp.float32),
            jnp.zeros((B, Hkv, G, Sq, D), jnp.float32))


def paged_softmax_finalize(q, m, l, acc):
    """(m, l, acc) -> (B, Sq, Hq, D) output in q.dtype."""
    B, Sq, Hq, D = q.shape
    out = acc / jnp.maximum(l, 1e-30)
    out = out.transpose(0, 3, 1, 2, 4)               # (B,Sq,Hkv,G,D)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def dequant_gf_page(words, scale, *, p: int, k_info: int, page_shape,
                    dtype=jnp.bfloat16):
    """GF page(s) -> dequantized tensor, replicating
    `repro.memory.paged.dequantize_tensor` exactly (slice info symbols,
    desymbolize base-p digits, absmax-int8 dequant, cast).

    words: (..., W, n) int32 codeword page(s); scale: (...) f32 absmax
    scales (one per leading element). Returns (...,) + page_shape in
    `dtype`. Bit-exact against dequantize_tensor on the same words/meta."""
    import numpy as np
    from repro.memory.packing import desymbolize_u8, digits_per_byte
    lead = words.shape[:-2]
    numel = int(np.prod(page_shape))
    D = digits_per_byte(p)
    info = words[..., :k_info].astype(jnp.int32)
    digits = info.reshape(lead + (-1,))[..., :numel * D]
    digits = digits.reshape(lead + (numel, D))
    u8 = desymbolize_u8(digits, p)
    qv = u8.astype(jnp.float32) - 128.0
    out = (qv * jnp.reshape(scale, lead + (1,))).astype(dtype)
    return out.reshape(lead + tuple(page_shape))


def attend_protected_ref(q, kpages, vpages, kscales, vscales, valid,
                         hot_k, hot_v, hot_valid, *, p: int, k_info: int,
                         page_shape, softcap: float = 0.0,
                         with_hot: bool = True):
    """Fused protected-attention oracle: GF pages + scales + query block ->
    attention output, in ONE traced graph (dequant + online-softmax per
    page, no decoded K/V ever materialized between executables).

    kpages/vpages: (NP, S, W, n) int32 corrected GF pages — page step j is
    S sub-pages of `page_shape` = (Bsub, T, Hkv, D) stacked to the batch
    (S=1, Bsub=B for the single-tenant layer; S=B, Bsub=1 for the engine's
    per-slot pages). kscales/vscales: (NP, S) f32 absmax scales. valid:
    (NP, B) int32 per-step per-row valid tokens (0 rows are masked — pad
    pages and empty slots). hot_k/hot_v: (B, T, Hkv, D) dense hot page,
    applied last when `with_hot` with hot_valid (B,) fill levels.

    Per-page math is `paged_softmax_update` on pages dequantized by
    `dequant_gf_page` — the exact functions the unfused streaming path
    jits — so fused output is bit-identical to `_attend_paged` over the
    same pages."""
    B, Sq, Hq, D = q.shape
    Bsub, T, Hkv, Dh = page_shape
    G = Hq // Hkv
    NP = kpages.shape[0]
    m, l, acc = paged_softmax_init(B, Hkv, G, Sq, D)
    for j in range(NP):
        kpg = dequant_gf_page(kpages[j], kscales[j], p=p, k_info=k_info,
                              page_shape=page_shape, dtype=hot_k.dtype)
        vpg = dequant_gf_page(vpages[j], vscales[j], p=p, k_info=k_info,
                              page_shape=page_shape, dtype=hot_v.dtype)
        kpg = kpg.reshape(B, T, Hkv, Dh)
        vpg = vpg.reshape(B, T, Hkv, Dh)
        m, l, acc = paged_softmax_update(q, kpg, vpg, valid[j], m, l, acc,
                                         softcap=softcap)
    if with_hot:
        m, l, acc = paged_softmax_update(q, hot_k, hot_v, hot_valid,
                                         m, l, acc, softcap=softcap)
    return paged_softmax_finalize(q, m, l, acc)


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None):
    """Naive attention oracle. q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> like q.
    fp32 math throughout."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window:
        ok &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D)
