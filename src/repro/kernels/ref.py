"""Pure-jnp oracles for the Pallas kernels.

Each function is the semantic ground truth its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.decode import _cn_fbp_jnp, maxplus_conv  # noqa: F401


def fbp_cn_ref(m_hat: jnp.ndarray, p: int) -> jnp.ndarray:
    """m_hat: (N, dc, p) contribution-space messages (padded slots already hold
    the max-plus identity). Returns reflected extrinsic messages (N, dc, p)."""
    # _cn_fbp_jnp expects (B, c, dc, p); fold N into (N, 1, dc, p)
    out = _cn_fbp_jnp(m_hat[:, None], p)
    return out[:, 0]


def gf_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, p: int) -> jnp.ndarray:
    """(a @ b) mod p with exact int32 accumulation. a: (M, K), b: (K, N)."""
    return (a.astype(jnp.int32) @ b.astype(jnp.int32)) % p


def scan_syndromes_ref(y: jnp.ndarray, ht: jnp.ndarray, p: int) -> jnp.ndarray:
    """Unfused scrub-scan oracle: full syndrome matrix, then the any-reduce."""
    return (gf_matmul_ref(y, ht, p) != 0).any(axis=1)


def encode_words_ref(u: jnp.ndarray, P: jnp.ndarray, p: int) -> jnp.ndarray:
    """Systematic-encode oracle: [u | (u @ P) mod p], exact int32
    accumulation. u: (B, k) info symbols in [0, p); P: (k, c)."""
    return jnp.concatenate([u.astype(jnp.int32), gf_matmul_ref(u, P, p)],
                           axis=-1)


def pim_mac_ref(x: jnp.ndarray, w: jnp.ndarray, *, row_parallelism: int,
                adc_levels: int) -> jnp.ndarray:
    """Row-grouped ADC-quantized MAC. x: (B, K), w: (K, N); K divisible by the
    row-parallelism R. Partial sums of each R-row group are clipped to the ADC
    range before digital accumulation."""
    B, K = x.shape
    R = row_parallelism if row_parallelism > 0 else K
    assert K % R == 0
    g = K // R
    xg = x.astype(jnp.int32).reshape(B, g, R)
    wg = w.astype(jnp.int32).reshape(g, R, w.shape[1])
    partial = jnp.einsum("bgr,gro->bgo", xg, wg)
    if adc_levels > 0:
        half = adc_levels // 2
        partial = jnp.clip(partial, -half, half)
    return partial.sum(axis=1)


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None):
    """Naive attention oracle. q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> like q.
    fp32 math throughout."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window:
        ok &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D)
