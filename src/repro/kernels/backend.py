"""Kernel-backend policy shared by every Pallas kernel entry point.

One knob instead of three: `KernelPolicy` replaces the per-call
`interpret: bool | None` defaults, `PagedProtectedStore(backend=...)` and
`MemoryController(scan_backend=...)` that had each grown their own
auto/host/device vocabulary. A policy resolves to one of three modes:

- **compiled**  — native Pallas (Mosaic) kernels; only available on TPU.
- **interpret** — the Pallas interpreter: same kernel code, any backend.
  This is a *parity/validation* path, not a fast path.
- **ref**       — the pure-jnp oracles in `kernels/ref.py` (jitted). The
  fast path everywhere Mosaic can't compile, bit-identical to the kernels
  by the parity tests.

`KernelPolicy("auto")` (the default) resolves to `compiled` on TPU and
`ref` elsewhere — the dispatch every subsystem previously hand-rolled.
`use_policy(...)` installs a different policy for a `with` block so tests
and benches can force any mode:

    with use_policy("interpret"):
        out = ops.scan_syndromes(y, ht, p)      # Pallas interpreter on CPU

Resolution happens at trace/build time (backends don't change inside a
process), so cached executables bake in the mode that was current when
they were first built.

Legacy keywords (`backend=`, `scan_backend=`) are mapped onto policies by
`policy_from_store_backend` / `policy_from_scan_backend`; their call sites
emit a one-release `DeprecationWarning`.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax

__all__ = ["KernelPolicy", "current_policy", "use_policy", "resolve_mode",
           "resolve_interpret", "interpret_default",
           "policy_from_store_backend", "policy_from_scan_backend"]

MODES = ("auto", "compiled", "interpret", "ref")


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Where kernel work runs: auto | compiled | interpret | ref."""

    mode: str = "auto"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")

    def resolve(self) -> str:
        """The concrete mode: compiled / interpret / ref."""
        if self.mode != "auto":
            return self.mode
        return "compiled" if jax.default_backend() == "tpu" else "ref"

    @property
    def use_pallas(self) -> bool:
        """True when work should run through a Pallas kernel at all."""
        return self.resolve() != "ref"

    @property
    def interpret(self) -> bool:
        """The `interpret=` flag a Pallas call under this policy gets."""
        return self.resolve() != "compiled"


_current = KernelPolicy()


def _as_policy(policy) -> KernelPolicy:
    if isinstance(policy, KernelPolicy):
        return policy
    if isinstance(policy, str):
        return KernelPolicy(policy)
    raise TypeError(f"expected KernelPolicy or mode string, got {policy!r}")


def current_policy() -> KernelPolicy:
    return _current


@contextlib.contextmanager
def use_policy(policy):
    """Install `policy` (a KernelPolicy or a mode string) for the block."""
    global _current
    prev = _current
    _current = _as_policy(policy)
    try:
        yield _current
    finally:
        _current = prev


def resolve_mode(policy=None) -> str:
    """Concrete mode for `policy`, defaulting to the ambient policy."""
    pol = _current if policy is None else _as_policy(policy)
    return pol.resolve()


def resolve_interpret(interpret: bool | None, policy=None) -> bool:
    """Resolve a Pallas call's `interpret=` flag.

    Explicit booleans are honored (the low-level escape hatch); None defers
    to the policy — interpret everywhere except compiled-on-TPU, exactly the
    old `interpret_default()` contract under the default auto policy."""
    if interpret is not None:
        return bool(interpret)
    pol = _current if policy is None else _as_policy(policy)
    return pol.interpret


def interpret_default() -> bool:
    """True (interpret mode) unless the ambient policy compiles natively."""
    return _current.interpret


# ---------------------------------------------------------------------------
# legacy-keyword converters (one-release deprecated aliases)
# ---------------------------------------------------------------------------


def policy_from_store_backend(backend: str) -> KernelPolicy:
    """Map the old `PagedProtectedStore(backend=...)` vocabulary:
    auto -> auto, kernel -> the Pallas path (compiled on TPU, interpreter
    elsewhere — what `backend="kernel"` always meant), ref -> ref."""
    if backend not in ("auto", "kernel", "ref"):
        raise ValueError(f"backend {backend!r} not in ('auto', 'kernel', "
                         "'ref')")
    if backend == "auto":
        return KernelPolicy("auto")
    if backend == "ref":
        return KernelPolicy("ref")
    return KernelPolicy("compiled" if jax.default_backend() == "tpu"
                        else "interpret")


def policy_from_scan_backend(scan_backend: str) -> KernelPolicy:
    """Map the old `MemoryController(scan_backend=...)` vocabulary:
    auto -> auto, host -> ref (the exact host BLAS scan), device -> the
    Pallas kernel (compiled on TPU, interpreter elsewhere)."""
    if scan_backend not in ("auto", "host", "device"):
        raise ValueError(f"scan_backend {scan_backend!r} not in ('auto', "
                         "'host', 'device')")
    if scan_backend == "auto":
        return KernelPolicy("auto")
    if scan_backend == "host":
        return KernelPolicy("ref")
    return KernelPolicy("compiled" if jax.default_backend() == "tpu"
                        else "interpret")
