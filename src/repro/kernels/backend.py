"""Backend dispatch shared by every Pallas kernel entry point.

Kernels compile natively only on TPU; everywhere else (CPU unit tests,
GPU hosts without a Mosaic backend) they run under the Pallas interpreter.
Both the jitted public wrappers in `ops.py` and the raw `*_pallas`
entry points resolve their `interpret=None` default through this one
predicate so direct callers never silently interpret on a real TPU.
"""
from __future__ import annotations

import jax


def interpret_default() -> bool:
    """True (interpret mode) everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return interpret_default() if interpret is None else bool(interpret)
