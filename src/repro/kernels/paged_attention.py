"""Fused protected paged-attention Pallas kernel — the one-kernel serving
hot path.

The unfused serving read path interrupts the dataflow three times per KV
page: decode the GF page to symbols (HBM), dequantize to bf16 K/V (HBM),
then run one online-softmax update (HBM in, HBM out). This kernel takes
the *corrected GF pages themselves* plus their quantization scales and the
query block, and produces the attention output directly: per-page base-p
desymbolize + int8 dequant feed the flash-attention recurrence in VMEM
scratch, so corrected K/V never round-trips HBM — the paper's
no-dataflow-interruption property applied to serving.

Division of labor with the store: syndrome scanning and FBP correction of
*flagged* pages happen upstream (`PagedProtectedStore.read_page_corrected`,
the scan-gated fast path — clean pages skip the decoder entirely and most
pages are clean), so the pages this kernel consumes are already corrected
symbols; the kernel fuses everything after correction — desymbolize,
dequant, QKᵀ, online softmax, ·V accumulate.

Layout (page-granular flash attention, following `flash_attention.py`):
grid = (NP,) over page steps with the output block revisited every step;
fp32 (m, l, acc) running state lives in VMEM scratch; step j loads one
(S, W, n) GF page block + its (S,) scales + the (B,) per-row valid counts.
Page step j is S sub-pages of shape `page_shape` = (Bsub, T, Hkv, D)
stacked along batch (S=1 for the single-tenant layer, S=B for the serving
engine's per-slot pages). The dense hot page (tokens not yet frozen into
GF storage) is applied as a final update inside the same kernel, and the
last step writes `acc / l`.

In-kernel math is fp32 end-to-end (no bf16 round-trip between dequant and
QKᵀ — the corrected K/V exist only as VMEM fp32), so parity vs the
bit-exact jnp oracle (`ref.attend_protected_ref`, which replicates the
unfused path's bf16 casts) is allclose at bf16 tolerance, asserted by
tests/test_fused_attention.py. Validated in interpret mode on CPU; Mosaic
compilation on a real TPU is the ROADMAP's standing validation caveat.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import resolve_interpret

NEG_INF = -1e30


def _dequant_block(page, scale, *, p, k_info, numel, D):
    """(S, W, n) int32 GF page block + (S,) scales -> (S, numel) fp32.

    Replicates `repro.memory.paged.dequantize_tensor`: slice the systematic
    info symbols, clip digits into the field, little-endian base-p
    desymbolize mod 256, recentre the int8 code, absmax-scale."""
    S = page.shape[0]
    info = page[:, :, :k_info].astype(jnp.int32)
    digits = info.reshape(S, -1)[:, :numel * D].reshape(S, numel, D)
    digits = jnp.clip(digits, 0, p - 1)
    val = sum(digits[..., i] * p ** i for i in range(D)) % 256
    return (val.astype(jnp.float32) - 128.0) * scale[:, None]


def _update(q5, kpg, vpg, valid, m, l, acc, *, softcap):
    """One online-softmax update on the VMEM carries. q5: (B,Sq,Hkv,G,D)
    fp32; kpg/vpg: (B,T,Hkv,D) fp32; valid: (B,) int32."""
    T = kpg.shape[1]
    D = q5.shape[-1]
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q5, kpg)
    logits = logits / jnp.sqrt(jnp.float32(D))
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    ok = (jax.lax.iota(jnp.int32, T)[None, None, None, None, :]
          < valid.reshape(-1, 1, 1, 1, 1))
    logits = jnp.where(ok, logits, NEG_INF)
    pm = logits.max(axis=-1, keepdims=True)
    new_m = jnp.maximum(m[...], pm)
    w = jnp.exp(logits - new_m)
    corr = jnp.exp(m[...] - new_m)
    l[...] = corr * l[...] + w.sum(axis=-1, keepdims=True)
    acc[...] = corr * acc[...] + jnp.einsum(
        "bhgqk,bkhd->bhgqd", w, vpg)
    m[...] = new_m


def _kernel(kp_ref, vp_ref, ks_ref, vs_ref, valid_ref, q_ref, hk_ref,
            hv_ref, hval_ref, o_ref, m, l, acc, *, p, k_info, page_shape,
            softcap, nps, with_hot):
    j = pl.program_id(0)
    Bsub, T, Hkv, Dh = page_shape
    B, Sq, Hq, _ = q_ref.shape
    G = Hq // Hkv
    numel = Bsub * T * Hkv * Dh
    D = math.ceil(8.0 / math.log2(p))          # base-p digits per byte

    @pl.when(j == 0)
    def _init():
        m[...] = jnp.full_like(m, -jnp.inf)
        l[...] = jnp.zeros_like(l)
        acc[...] = jnp.zeros_like(acc)

    q5 = q_ref[...].astype(jnp.float32).reshape(B, Sq, Hkv, G, Dh)
    kpg = _dequant_block(kp_ref[0], ks_ref[0], p=p, k_info=k_info,
                         numel=numel, D=D).reshape(B, T, Hkv, Dh)
    vpg = _dequant_block(vp_ref[0], vs_ref[0], p=p, k_info=k_info,
                         numel=numel, D=D).reshape(B, T, Hkv, Dh)
    _update(q5, kpg, vpg, valid_ref[0], m, l, acc, softcap=softcap)

    @pl.when(j == nps - 1)
    def _fin():
        if with_hot:
            _update(q5, hk_ref[...].astype(jnp.float32),
                    hv_ref[...].astype(jnp.float32), hval_ref[...],
                    m, l, acc, softcap=softcap)
        out = acc[...] / jnp.maximum(l[...], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4)      # (B,Sq,Hkv,G,D)
        o_ref[...] = out.reshape(B, Sq, Hq, Dh).astype(o_ref.dtype)


def attend_protected_pallas(q, kpages, vpages, kscales, vscales, valid,
                            hot_k, hot_v, hot_valid, *, p: int, k_info: int,
                            page_shape, softcap: float = 0.0,
                            with_hot: bool = True,
                            interpret: bool | None = None):
    """Raw kernel entry point (shape contract in `ref.attend_protected_ref`;
    use `ops.attend_protected` for policy dispatch + page bucketing).
    kpages/vpages: (NP, S, W, n) with NP >= 1."""
    NP, S, W, n = kpages.shape
    B, Sq, Hq, Dh = q.shape
    Bsub, T, Hkv, _ = page_shape
    G = Hq // Hkv
    kern = functools.partial(_kernel, p=p, k_info=k_info,
                             page_shape=tuple(page_shape), softcap=softcap,
                             nps=NP, with_hot=with_hot)
    page_spec = pl.BlockSpec((1, S, W, n), lambda j: (j, 0, 0, 0))
    scale_spec = pl.BlockSpec((1, S), lambda j: (j, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda j: (0,) * len(shape))
    return pl.pallas_call(
        kern,
        grid=(NP,),
        in_specs=[
            page_spec, page_spec, scale_spec, scale_spec,
            pl.BlockSpec((1, B), lambda j: (j, 0)),
            full((B, Sq, Hq, Dh)),
            full((B, T, Hkv, Dh)),
            full((B, T, Hkv, Dh)),
            full((B,)),
        ],
        out_specs=full((B, Sq, Hq, Dh)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((B, Hkv, G, Sq, 1), jnp.float32),
            pltpu.VMEM((B, Hkv, G, Sq, 1), jnp.float32),
            pltpu.VMEM((B, Hkv, G, Sq, Dh), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(kpages, vpages, kscales, vscales, valid, q, hot_k, hot_v, hot_valid)
