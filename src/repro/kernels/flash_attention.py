"""Pallas TPU flash attention (fwd + bwd) — the framework's attention
hot-spot kernel.

Why it exists here: the dry-run baselines show the memory roofline term of
nearly every (arch x shape) cell is dominated by the O(Sq*Skv) attention
intermediates (logits/softmax/probability tensors hitting HBM). The flash
formulation keeps them VMEM-resident: HBM traffic becomes O(S*D) for
q/k/v/o (+ the (B,H,S) logsumexp), which is what the §Perf iterations claim
for the memory term.

Layout: heads are folded into the leading grid axis. q: (BH, Sq, D);
k/v: (BHkv, Skv, D); GQA maps grid row b -> kv row via b//G computed inside
the index_map. Grid = (BH, nq, nkv) with the KV axis innermost; the output
block (and the fp32 m/l/acc running state in VMEM scratch) is revisited
across the KV steps — the standard online-softmax recurrence:

    m' = max(m, rowmax(s));  c = exp(m - m')
    l' = l*c + rowsum(exp(s - m'));  acc' = acc*c + exp(s - m') @ v

Masking (causal / sliding-window) is computed from global indices via iota,
so padded tails and ring-buffer decode windows need no mask tensors in HBM.
Soft-capping (gemma2) is applied to the raw scores in both fwd and bwd
(derivative recomputed from the capped value: d tanh = 1 - tanh^2).

VMEM working set per grid step (bq = BLOCK_Q = 512, bk = BLOCK_KV = 512,
D = 128, fp32 scratch): q 512x128x2B + k/v 2x512x128x2B + s/p 512x512x4B +
acc 512x128x4B + m/l 2x512x4B ~= 1.6 MiB — comfortably inside a v5e core's
VMEM with double-buffering headroom.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import resolve_interpret

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def _mask(qpos, kpos, causal: bool, window: int, kv_len: int = 0):
    ok = jnp.ones(qpos.shape[:1] + kpos.shape[:1], jnp.bool_)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window:
        ok &= kpos[None, :] > qpos[:, None] - window
    if kv_len:                       # padded KV tail (non-block-aligned Skv)
        ok &= kpos[None, :] < kv_len
    return ok


def _scores(q, k, scale, softcap):
    s = jax.lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc, m, l, *,
                scale, causal, window, softcap, bq, bk, nkv, kv_len=0):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    i = pl.program_id(1)
    qpos = i * bq + jax.lax.iota(jnp.int32, bq)
    kpos = j * bk + jax.lax.iota(jnp.int32, bk)

    # skip kv blocks that the causal/window mask fully excludes
    run = jnp.asarray(True)
    if causal:
        run &= (j * bk) <= ((i + 1) * bq - 1)
    if window:
        run &= ((j + 1) * bk - 1) > (i * bq - window)

    @pl.when(run)
    def _step():
        s = _scores(q_ref[0], k_ref[0], scale, softcap)      # (bq, bk) f32
        ok = _mask(qpos, kpos, causal, window, kv_len)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m[...], s.max(axis=-1))
        # guard fully-masked rows (exp(NEG_INF - NEG_INF) = 1 otherwise)
        alive = m_new > NEG_INF / 2
        p = jnp.where(alive[:, None], jnp.exp(s - m_new[:, None]), 0.0)
        c = jnp.where(alive, jnp.exp(m[...] - m_new), 1.0)
        l[...] = l[...] * c + p.sum(axis=-1)
        acc[...] = acc[...] * c[:, None] + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m[...] = m_new

    @pl.when(j == nkv - 1)
    def _fin():
        safe_l = jnp.maximum(l[...], 1e-30)
        o_ref[0] = (acc[...] / safe_l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m[...] + jnp.log(safe_l)


def flash_fwd(q, k, v, *, g: int, scale: float, causal: bool, window: int,
              softcap: float, bq: int = DEFAULT_BLOCK_Q,
              bk: int = DEFAULT_BLOCK_KV, kv_len: int = 0,
              interpret: bool | None = None):
    """q: (BH, Sq, D); k/v: (BHkv, Skv, D); g = Hq//Hkv (GQA group).
    Returns (o (BH, Sq, D), lse (BH, Sq) fp32). `interpret=None` resolves
    through the shared backend policy (compiled on TPU) — a hardcoded True
    here used to silently interpret on real hardware."""
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nkv = Sq // bq, Skv // bk
    kern = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                             window=window, softcap=softcap, bq=bq, bk=bk,
                             nkv=nkv, kv_len=kv_len)
    kv_map = lambda b, i, j: (b // g, j, 0)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward: dq (grid over q blocks) and dk/dv (grid over kv blocks)
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   acc, *, scale, causal, window, softcap, bq, bk, nkv,
                   kv_len=0):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    i = pl.program_id(1)
    qpos = i * bq + jax.lax.iota(jnp.int32, bq)
    kpos = j * bk + jax.lax.iota(jnp.int32, bk)

    s = _scores(q_ref[0], k_ref[0], scale, softcap)
    ok = _mask(qpos, kpos, causal, window, kv_len)
    p = jnp.where(ok, jnp.exp(s - lse_ref[0][:, None]), 0.0)     # (bq, bk)
    dp = jax.lax.dot_general(do_ref[0].astype(jnp.float32),
                             v_ref[0].astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None])                        # dL/ds
    if softcap:
        ds = ds * (1.0 - (s / softcap) ** 2)
    ds = ds * scale
    acc[...] += jax.lax.dot_general(ds, k_ref[0].astype(jnp.float32),
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(j == nkv - 1)
    def _fin():
        dq_ref[0] = acc[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, acck, accv, *,
                    scale, causal, window, softcap, bq, bk, nq, kv_len=0):
    i = pl.program_id(2)          # q blocks innermost

    @pl.when(i == 0)
    def _init():
        acck[...] = jnp.zeros_like(acck)
        accv[...] = jnp.zeros_like(accv)

    j = pl.program_id(1)
    qpos = i * bq + jax.lax.iota(jnp.int32, bq)
    kpos = j * bk + jax.lax.iota(jnp.int32, bk)

    s = _scores(q_ref[0], k_ref[0], scale, softcap)
    ok = _mask(qpos, kpos, causal, window, kv_len)
    p = jnp.where(ok, jnp.exp(s - lse_ref[0][:, None]), 0.0)     # (bq, bk)
    accv[...] += jax.lax.dot_general(p, do_ref[0].astype(jnp.float32),
                                     (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do_ref[0].astype(jnp.float32),
                             v_ref[0].astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None])
    if softcap:
        ds = ds * (1.0 - (s / softcap) ** 2)
    ds = ds * scale
    acck[...] += jax.lax.dot_general(ds, q_ref[0].astype(jnp.float32),
                                     (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)

    @pl.when(i == nq - 1)
    def _fin():
        dk_ref[0] = acck[...].astype(dk_ref.dtype)
        dv_ref[0] = accv[...].astype(dv_ref.dtype)


def flash_bwd(q, k, v, o, lse, do, *, g: int, scale: float, causal: bool,
              window: int, softcap: float, bq: int = DEFAULT_BLOCK_Q,
              bk: int = DEFAULT_BLOCK_KV, kv_len: int = 0,
              interpret: bool | None = None):
    """Returns (dq (BH,Sq,D), dk_h (BH,Skv,D), dv_h (BH,Skv,D)) — dk/dv are
    per-q-head; the wrapper sums groups of g to get the kv-head grads."""
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    BHkv, Skv, _ = k.shape
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    nq, nkv = Sq // bq, Skv // bk
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)  # (BH,Sq)

    kv_map = lambda b, i, j: (b // g, j, 0)
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, bq=bq, bk=bk,
                          nkv=nkv, kv_len=kv_len),
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),   # q
            pl.BlockSpec((1, bk, D), kv_map),                      # k
            pl.BlockSpec((1, bk, D), kv_map),                      # v
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),   # do
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),         # lse
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),         # delta
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    kv_map2 = lambda b, j, i: (b // g, j, 0)
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, bq=bq, bk=bk,
                          nq=nq, kv_len=kv_len),
        grid=(BH, nkv, nq),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, j, i: (b, i, 0)),   # q
            pl.BlockSpec((1, bk, D), kv_map2),                     # k
            pl.BlockSpec((1, bk, D), kv_map2),                     # v
            pl.BlockSpec((1, bq, D), lambda b, j, i: (b, i, 0)),   # do
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),         # lse
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),         # delta
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Skv, D), k.dtype),
            jax.ShapeDtypeStruct((BH, Skv, D), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
