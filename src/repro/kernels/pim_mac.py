"""Pallas TPU kernel: bit-line PIM MAC with per-row-group ADC quantization.

Models the analog accumulate + flash-ADC sample path (paper §2.1, Fig. 1(a)):
partial sums over `row_parallelism` wordlines are clipped to the ADC range
before digital accumulation. On TPU this is a K-blocked matmul whose K-block
equals the row-parallelism group, with the clip fused between the MXU dot and
the accumulate — the quantization epilogue rides in VMEM, never spilling
partials to HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import resolve_interpret


def _pim_mac_kernel(x_ref, w_ref, o_ref, *, groups_per_block: int, R: int,
                    adc_half: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)        # (bm, bk) with bk = groups_per_block*R
    w = w_ref[...].astype(jnp.int32)        # (bk, bn)
    acc = jnp.zeros_like(o_ref)
    for g in range(groups_per_block):
        xs = x[:, g * R:(g + 1) * R]
        ws = w[g * R:(g + 1) * R, :]
        partial = jax.lax.dot_general(
            xs, ws, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        if adc_half > 0:
            partial = jnp.clip(partial, -adc_half, adc_half)
        acc += partial
    o_ref[...] += acc


def pim_mac_pallas(x: jnp.ndarray, w: jnp.ndarray, *, row_parallelism: int,
                   adc_levels: int, bm: int = 128, bn: int = 128,
                   groups_per_block: int = 1,
                   interpret: bool | None = None) -> jnp.ndarray:
    """x: (B, K) int, w: (K, N) int -> (B, N) int32 group-quantized MAC.

    K must be a multiple of row_parallelism * groups_per_block (caller pads —
    zero rows are exact no-ops for the clip since clip(0)=0 contributes 0).
    """
    B, K = x.shape
    K2, N = w.shape
    assert K == K2
    R = row_parallelism if row_parallelism > 0 else K
    bk = R * groups_per_block
    assert K % bk == 0, f"K={K} not a multiple of group block {bk}"
    assert B % bm == 0 and N % bn == 0
    nk = K // bk
    kern = functools.partial(_pim_mac_kernel, groups_per_block=groups_per_block,
                             R=R, adc_half=adc_levels // 2 if adc_levels > 0 else 0,
                             nk=nk)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        grid=(B // bm, N // bn, nk),
        interpret=resolve_interpret(interpret),
    )(x, w)
