"""Pallas TPU kernels for the paper's compute hot-spots (validated on CPU with
interpret=True against the pure-jnp oracles in ref.py). Backend selection —
compiled / interpret / ref — is one `KernelPolicy` (backend.py)."""
from . import ops, ref
from .backend import (KernelPolicy, current_policy, resolve_interpret,
                      resolve_mode, use_policy)
from .ops import (attend_protected, fbp_cn, fbp_cn_batched, gf_matmul,
                  pim_mac, scan_syndromes)
