"""Pallas TPU kernels for the paper's compute hot-spots (validated on CPU with
interpret=True against the pure-jnp oracles in ref.py)."""
from . import ops, ref
from .ops import fbp_cn, fbp_cn_batched, gf_matmul, pim_mac, scan_syndromes
