"""Protected KV-cache serving: NB-LDPC memory-mode protection under live
inference (the ROADMAP "Protected KV-cache serving" scenario).

Self-attention K/V pages live in a device-resident
`repro.memory.paged.PagedProtectedStore` instead of raw jnp buffers:

- **append** — tokens accumulate in a small dense *hot page*
  (`page_tokens` slots); when it fills, the page is absmax-int8 quantized,
  symbolized to GF(p) levels and device-encoded into the store (one
  fixed-shape encode executable per layer — write-time encode, the paper's
  no-interruption property);
- **read** — attention consumes pages through a streaming online-softmax
  (`repro.nn.layers._attend_paged`); frozen pages decode through the
  overlap pipeline (`PagedProtectedStore.iter_corrected`: page *i+1*'s
  scan/decode dispatched while page *i* is consumed) and the dequantized
  views are memoized until storage is corrupted (`inject`) — the decoder
  sits under the read cache, off the per-token hot path;
- **quality ablation** — `corrected=False` reads raw (possibly corrupted)
  levels, the unprotected baseline the serving benchmark compares against;
  `overlap=False` blocks on every page (synchronous whole-cache decode),
  the no-pipelining ablation.

Layer coverage: global self-attention layers ("attn", non-cross, no sliding
window) are protected; mamba states, cross-attention K/V and sliding-window
rings keep their dense caches (ring eviction under paged ECC is future
work). `repro.models.lm.init_caches(..., protected_kv=...)` builds the
manager, `prefill` ingests the prompt K/V, and `decode_step` serves through
it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.memory.paged import (PagedProtectedStore, dequantize_tensor,
                                quantize_tensor, words_for_tensor)
from repro.memory.pool import PooledStore, ProtectedPagePool
from repro.nn.kv_source import KVSource
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = ["ProtectedKVConfig", "ProtectedKVLayer", "ProtectedKVCaches"]


@dataclasses.dataclass(frozen=True)
class ProtectedKVConfig:
    """Knobs for the protected KV serving path."""

    code_name: str = "wl160_r08"
    page_tokens: int = 16          # tokens per frozen (encoded) page
    n_iters: int = 8               # FBP iterations on flagged pages
    damping: float = 0.3
    corrected: bool = True         # False: raw-level reads (unprotected
                                   # quality ablation — same quantization,
                                   # no correction)
    fused: bool = True             # serve corrected reads through the fused
                                   # GF-page attention kernel
                                   # (ops.attend_protected); False streams
                                   # decoded pages through _attend_paged
                                   # (the exact-parity reference path)
    overlap: bool = True           # False: block on every page decode
                                   # (synchronous whole-cache ablation)
    mesh: Any = None               # shard pages across a local device mesh
    pool: Any = None               # ProtectedPagePool: back every layer's
                                   # stores with shared pool pages (block
                                   # tables) instead of private grow-only
                                   # storage — the multi-tenant path


class ProtectedKVLayer(KVSource):
    """One self-attention layer's protected K/V: two paged stores (K and V),
    a dense hot page, and a memoized decoded view. Implements `KVSource`:
    `attend` serves corrected reads through the fused GF-page attention
    kernel (the one-kernel hot path) and keeps the streaming
    `pages()`/`_attend_paged` path as the exact-parity reference."""

    kind = "protected"

    def __init__(self, pkv: ProtectedKVConfig, batch: int, hkv: int,
                 dh: int, dtype=jnp.bfloat16, owner: Any = None):
        self.pkv = pkv
        self.batch, self.hkv, self.dh = batch, hkv, dh
        self.dtype = dtype
        self.owner = owner
        self.page_shape = (batch, pkv.page_tokens, hkv, dh)
        from repro.core import get_code
        code = get_code(pkv.code_name)
        # one frozen KV page == exactly one store page, so the store's
        # pipelined page iterator IS the layer's page iterator
        wpu = words_for_tensor(self.page_shape, code.p, code.k)
        if pkv.pool is not None:
            pool: ProtectedPagePool = pkv.pool
            if pool.page_words != wpu:
                raise ValueError(
                    f"pool page_words={pool.page_words} != {wpu} words per "
                    f"KV page for page_shape {self.page_shape}; size the "
                    "pool with words_for_tensor(page_shape, p, k)")
            if (pool.code.n, pool.code.k, pool.code.p) != (code.n, code.k,
                                                           code.p):
                raise ValueError(
                    f"pool code ({pool.code.n},{pool.code.k},p{pool.code.p})"
                    f" != KV code ({code.n},{code.k},p{code.p})")
            self.k_store = PooledStore(pool, owner=owner)
            self.v_store = PooledStore(pool, owner=owner)
        else:
            store_kw = dict(n_iters=pkv.n_iters, damping=pkv.damping,
                            mesh=pkv.mesh)
            self.k_store = PagedProtectedStore(code, page_words=wpu,
                                               **store_kw)
            self.v_store = PagedProtectedStore(code, page_words=wpu,
                                               **store_kw)
            # tag standalone stores with the layer's owner so corrected
            # reads attribute to the right RAS-estimator region (pool-backed
            # stores carry it natively)
            self.k_store.owner = owner
            self.v_store.owner = owner
        self.words_per_page = wpu
        self._inject_key = jax.random.PRNGKey(0)
        self._injections = 0
        self.hot_k = jnp.zeros(self.page_shape, dtype)
        self.hot_v = jnp.zeros(self.page_shape, dtype)
        self.hot_len = 0
        self.n_frozen = 0              # frozen tokens (== pages * page_tokens)
        self._metas: list = []         # per frozen page: (k_meta, v_meta)
        self._decoded: list | None = None   # memoized [(k_pg, v_pg)]
        # fused-path memo: corrected GF codeword pages [(k_words, v_words)]
        # (what attend_protected consumes — symbols, not dequantized K/V)
        self._gf_pages: list | None = None
        self._gf_stack = None          # stacked (NP,1,W,n)/(NP,1) arrays

    # -- write path ---------------------------------------------------------

    @property
    def n_tokens(self) -> int:
        return self.n_frozen + self.hot_len

    def append(self, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Append (B, t, Hkv, D) new-token K/V (RoPE already applied, like
        the dense cache path). Fills the hot page; every time it reaches
        `page_tokens` tokens the page is quantized + device-encoded into
        the stores."""
        t = k.shape[1]
        done = 0
        while done < t:
            take = min(t - done, self.pkv.page_tokens - self.hot_len)
            self.hot_k = jax.lax.dynamic_update_slice_in_dim(
                self.hot_k, k[:, done:done + take].astype(self.dtype),
                self.hot_len, axis=1)
            self.hot_v = jax.lax.dynamic_update_slice_in_dim(
                self.hot_v, v[:, done:done + take].astype(self.dtype),
                self.hot_len, axis=1)
            self.hot_len += take
            done += take
            if self.hot_len == self.pkv.page_tokens:
                self._freeze()

    def _freeze(self) -> None:
        code = self.k_store.code
        with obs_trace.span("kv.freeze", owner=str(self.owner)):
            kw, kmeta = quantize_tensor(self.hot_k, code.p, code.k)
            vw, vmeta = quantize_tensor(self.hot_v, code.p, code.k)
            self.k_store.append_words(kw)
            self.v_store.append_words(vw)
        reg = obs_metrics.current()
        if reg.enabled:
            reg.counter("kv_pages_frozen", layer="kv",
                        tenant=str(self.owner) if self.owner is not None
                        else "").inc()
        self._metas.append((kmeta, vmeta))
        if self._decoded is not None:
            # write-through: storage was just written clean, so the decoded
            # view of this page is the dequantized pre-encode words
            self._decoded.append((dequantize_tensor(kw, kmeta, code.p),
                                  dequantize_tensor(vw, vmeta, code.p)))
        if self._gf_pages is not None:
            # fused-path write-through: the store page just written IS the
            # corrected codeword page (one KV page == one store page)
            j = self.k_store.n_pages - 1
            self._gf_pages.append((self.k_store.page(j),
                                   self.v_store.page(j)))
            self._gf_stack = None
        self.n_frozen += self.pkv.page_tokens
        self.hot_len = 0

    # -- read path ----------------------------------------------------------

    def invalidate(self) -> None:
        """Drop the memoized decoded views (storage changed under them)."""
        self._decoded = None
        self._gf_pages = None
        self._gf_stack = None

    def inject(self, channel, key=None, **kw) -> int:
        """Corrupt both stores through a channel model; invalidates the
        decoded view so the next read goes through the decoder. The K and V
        stores draw from independent halves of the key (with no key, from a
        per-layer counter), so the two stores never see identical error
        patterns."""
        if key is None:
            key = jax.random.fold_in(self._inject_key, self._injections)
        elif isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self._injections += 1
        kk, vk = jax.random.split(key)
        changed = self.k_store.inject(channel, kk, **kw)
        changed += self.v_store.inject(channel, vk, **kw)
        self.invalidate()
        tr = obs_trace.current()
        if tr.enabled:
            tr.instant("kv.inject", owner=str(self.owner), cells=changed)
        reg = obs_metrics.current()
        if reg.enabled:
            reg.counter("kv_cells_injected", layer="kv",
                        tenant=str(self.owner) if self.owner is not None
                        else "").inc(changed)
        return changed

    def free(self) -> None:
        """Release the stores (pool-backed layers return every block to the
        shared free list) and reset the hot page."""
        self.k_store.free()
        self.v_store.free()
        self.hot_k = jnp.zeros(self.page_shape, self.dtype)
        self.hot_v = jnp.zeros(self.page_shape, self.dtype)
        self.hot_len = 0
        self.n_frozen = 0
        self._metas = []
        self._decoded = None
        self._gf_pages = None
        self._gf_stack = None

    def _refill_iter(self):
        """Decode + dequantize the frozen pages, one at a time.

        Overlap mode streams both stores through the double-buffered
        pipeline (`PagedProtectedStore.iter_corrected`: scan-gated decode of
        page i+1 dispatched while page i's consumer runs) and never blocks —
        the attention updates interleave with the decode queue. Sync mode
        (the whole-cache-decode ablation) decodes every page unconditionally
        and blocks on each before moving on. corrected=False reads raw
        levels (the unprotected-quality ablation)."""
        p = self.k_store.code.p
        kcode = self.k_store.code.k
        if not self.pkv.corrected:
            pages = zip(self.k_store._iter_pages(),
                        self.v_store._iter_pages(), strict=True)
        elif self.pkv.overlap:
            pages = zip(self.k_store.iter_corrected(depth=1),
                        self.v_store.iter_corrected(depth=1), strict=True)
        else:
            def sync_pages():
                for i in range(self.k_store.n_pages):
                    kp = self.k_store._decoder()(self.k_store.page(i))[1]
                    vp = self.v_store._decoder()(self.v_store.page(i))[1]
                    yield (jax.block_until_ready(kp.symbols),
                           jax.block_until_ready(vp.symbols))
            pages = sync_pages()
        for (kpg, vpg), (kmeta, vmeta) in zip(pages, self._metas,
                                              strict=True):
            kd = dequantize_tensor(kpg[:, :kcode], kmeta, p)
            vd = dequantize_tensor(vpg[:, :kcode], vmeta, p)
            if not self.pkv.overlap:
                kd = jax.block_until_ready(kd)
                vd = jax.block_until_ready(vd)
            yield kd, vd

    def pages(self):
        """Yield (k_page (B, T, Hkv, D), v_page, valid_tokens) in order —
        the iterator `repro.nn.layers._attend_paged` consumes. Frozen pages
        come from the memoized decoded view; when storage was corrupted
        (`inject`) the refill STREAMS through the decode pipeline directly
        into the consumer, memoizing as it goes, so ECC decode overlaps
        attention instead of preceding it. The hot page rides last."""
        T = self.pkv.page_tokens
        if self._decoded is not None:
            yield from ((kd, vd, T) for kd, vd in self._decoded)
        else:
            acc = []
            for kd, vd in self._refill_iter():
                acc.append((kd, vd))
                yield kd, vd, T
            self._decoded = acc          # only on full consumption
        if self.hot_len:
            yield self.hot_k, self.hot_v, self.hot_len

    # -- fused read path ----------------------------------------------------

    def _refill_gf(self) -> list:
        """Corrected GF codeword pages for the fused kernel, page by page
        through the scan-gated `read_page_corrected` (clean pages skip the
        decoder; corrections land in the stores' stats). Memoized until
        storage is corrupted, with `_freeze` write-through appends."""
        if self._gf_pages is None:
            self._gf_pages = [
                (self.k_store.read_page_corrected(i),
                 self.v_store.read_page_corrected(i))
                for i in range(self.k_store.n_pages)]
            self._gf_stack = None
        return self._gf_pages

    def _fused_inputs(self):
        """Stacked kernel operands: kpages/vpages (NB, 1, W, n) int32,
        kscales/vscales (NB, 1) f32, valid (NB, B) int32 (frozen pages are
        always full). Pre-padded to the `np_bucket` size with no-op zero
        pages here — at freeze time, not per decode step — so the per-step
        `attend_protected` call sees an already-bucketed page axis and
        issues exactly one dispatch. Memoized between freezes."""
        gf = self._refill_gf()
        if self._gf_stack is None:
            from repro.kernels.ops import np_bucket
            code = self.k_store.code
            NP, NB = len(gf), np_bucket(len(gf))
            kp = jnp.zeros((NB, 1, self.words_per_page, code.n), jnp.int32)
            vp = jnp.zeros_like(kp)
            ks = vs = jnp.zeros((NB, 1), jnp.float32)
            if gf:
                kp = kp.at[:NP].set(jnp.stack([k for k, _ in gf])[:, None])
                vp = vp.at[:NP].set(jnp.stack([v for _, v in gf])[:, None])
                ks = ks.at[:NP, 0].set(jnp.asarray(
                    [km.scale for km, _ in self._metas], jnp.float32))
                vs = vs.at[:NP, 0].set(jnp.asarray(
                    [vm.scale for _, vm in self._metas], jnp.float32))
            valid = jnp.zeros((NB, self.batch), jnp.int32).at[:NP].set(
                self.pkv.page_tokens)
            self._gf_stack = (kp, vp, ks, vs, valid)
        return self._gf_stack

    def attend(self, q, softcap=0.0):
        """Fused one-kernel read: corrected GF pages + scales + query ->
        attention output via `ops.attend_protected` (desymbolize + dequant
        + online softmax never leave the kernel). Falls back to the
        streaming `pages()` path when fusion is off or reads are
        uncorrected (the quality ablation reads raw levels, which only the
        streaming path models)."""
        if not (self.pkv.fused and self.pkv.corrected):
            return super().attend(q, softcap)
        if self.n_frozen == 0 and self.hot_len == 0:
            raise ValueError("paged attention needs at least one KV page")
        kp, vp, ks, vs, valid = self._fused_inputs()
        code = self.k_store.code
        from repro.kernels import ops
        return ops.attend_protected(
            q, kp, vp, ks, vs, valid, self.hot_k, self.hot_v,
            jnp.full((self.batch,), self.hot_len, jnp.int32),
            p=code.p, k_info=code.k, page_shape=self.page_shape,
            softcap=float(softcap or 0.0), with_hot=self.hot_len > 0)

    # -- stats --------------------------------------------------------------

    def stats(self) -> dict:
        ks, vs = self.k_store.stats, self.v_store.stats
        return {"tokens": self.n_tokens, "frozen_pages": len(self._metas),
                "stored_words": self.k_store.n_words + self.v_store.n_words,
                "stored_cells": self.k_store.n_cells + self.v_store.n_cells,
                "flagged_words": int(self.k_store.scan_flags().sum()
                                     + self.v_store.scan_flags().sum()),
                "detected": ks.detected + vs.detected,
                "corrected": ks.corrected + vs.corrected,
                "uncorrectable": ks.uncorrectable + vs.uncorrectable}


class ProtectedKVCaches:
    """Whole-model protected decode caches: `ProtectedKVLayer` per global
    self-attention layer, dense dicts for everything else (mamba state,
    cross K/V, sliding-window rings). The pytree-shaped `view`/`update`
    surface is what `repro.models.lm._apply_block` consumes, so the block
    code is identical for protected and dense serving."""

    def __init__(self, cfg: ArchConfig, pkv: ProtectedKVConfig, batch: int,
                 max_seq: int, owner: Any = None):
        from .lm import _block_cache                     # lazy: avoid cycle
        self.cfg, self.pkv = cfg, pkv
        self.batch, self.max_seq = batch, max_seq
        self.owner = owner
        n_aux = cfg.n_aux_tokens or 1
        self.layers: dict[tuple[int, int], ProtectedKVLayer] = {}
        self.dense: dict[tuple[int, int], dict] = {}
        for g in range(cfg.n_groups):
            for i, spec in enumerate(cfg.group_spec):
                if self._protectable(spec):
                    self.layers[(g, i)] = ProtectedKVLayer(
                        pkv, batch, cfg.n_kv_heads, cfg.head_dim,
                        owner=owner)
                else:
                    self.dense[(g, i)] = _block_cache(spec, cfg, batch,
                                                      max_seq, n_aux)
        self._inject_key = jax.random.PRNGKey(0)
        self._injections = 0

    @staticmethod
    def _protectable(spec) -> bool:
        return (spec.kind == "attn" and not spec.cross
                and not spec.local_window)

    # -- the _apply_block surface -------------------------------------------

    def view(self, g: int, i: int):
        if (g, i) in self.layers:
            return self.layers[(g, i)]           # a KVSource
        return self.dense[(g, i)]

    def update(self, g: int, i: int, new_cache: dict | None) -> None:
        if not new_cache or (g, i) in self.layers:
            return
        self.dense[(g, i)].update(new_cache)

    # -- prefill ingest -----------------------------------------------------

    def ingest_prefill(self, caches, S: int) -> None:
        """Adopt the stacked cache pytree a `prefill` pass produced: the
        prompt K/V of protected layers is appended (quantize + device
        encode, page by page); dense entries are re-homed into their
        max-seq buffers."""
        for i in range(len(self.cfg.group_spec)):
            entry = caches[f"pos{i}"]
            for g in range(self.cfg.n_groups):
                sliced = jax.tree.map(lambda t, g=g: t[g], entry)
                if (g, i) in self.layers:
                    self.layers[(g, i)].append(sliced["k"][:, :S],
                                               sliced["v"][:, :S])
                else:
                    dst = self.dense[(g, i)]
                    for name, val in sliced.items():
                        buf = dst[name]
                        if buf.shape == val.shape:
                            dst[name] = val
                        else:
                            pad = [(0, d - s) for d, s in
                                   zip(buf.shape, val.shape, strict=True)]
                            dst[name] = jnp.pad(val, pad)

    # -- maintenance / stats ------------------------------------------------

    def inject(self, channel, key: Any | None = None, **kw) -> int:
        """Corrupt every protected layer's stores and invalidate their
        decoded views. Each layer draws an independent fold_in-derived
        subkey (and splits it again for K vs V inside the layer), so no two
        layers — and no two repeated default-key injections — ever see the
        same error pattern."""
        if key is None:
            base = jax.random.fold_in(self._inject_key, self._injections)
        elif isinstance(key, int):
            base = jax.random.PRNGKey(key)
        else:
            base = key
        self._injections += 1
        changed = 0
        for j, layer in enumerate(sorted(self.layers)):
            changed += self.layers[layer].inject(
                channel, jax.random.fold_in(base, j), **kw)
        return changed

    def free(self) -> None:
        """Release every protected layer's storage (pool-backed layers
        return their blocks to the shared pool)."""
        for layer in self.layers.values():
            layer.free()

    def invalidate(self) -> None:
        for layer in self.layers.values():
            layer.invalidate()

    def scrub(self) -> dict:
        rep = {"flagged_words": 0, "repaired_words": 0}
        for layer in self.layers.values():
            for store in (layer.k_store, layer.v_store):
                r = store.scrub()
                rep["flagged_words"] += r["flagged_words"]
                rep["repaired_words"] += r["repaired_words"]
            layer.invalidate()
        return rep

    def stats(self) -> dict:
        per = [ly.stats() for ly in self.layers.values()]
        return {"protected_layers": len(self.layers),
                "dense_layers": len(self.dense),
                "tokens": per[0]["tokens"] if per else 0,
                "stored_words": sum(s["stored_words"] for s in per),
                "stored_cells": sum(s["stored_cells"] for s in per),
                "flagged_words": sum(s["flagged_words"] for s in per)}


def protected_overhead(cfg: ArchConfig, pkv: ProtectedKVConfig) -> dict:
    """Static storage accounting: cells per token for the protected vs raw
    dense cache (rate loss = check overhead x symbolization density)."""
    from repro.core import get_code
    from repro.memory.packing import digits_per_byte
    code = get_code(pkv.code_name)
    bytes_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim          # int8 K + V
    digits = bytes_per_tok * digits_per_byte(code.p)
    return {"code": pkv.code_name, "rate": code.k / code.n,
            "cells_per_token": digits / code.rate,
            "int8_bytes_per_token": bytes_per_tok}
