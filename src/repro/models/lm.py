"""Config-driven transformer / hybrid / SSM model stacks.

One code path builds every assigned architecture from its `ArchConfig`:
  - the stack is `n_groups` repetitions of `cfg.group_spec` (a tuple of
    LayerSpec); parameters for each group position are *stacked* over
    `n_groups` and the stack is executed with `lax.scan` (+ optional remat) —
    compile time and HLO size stay O(group), not O(depth);
  - layer kinds: "attn" (self- or cross-), "mamba" (selective SSM), "encdec"
    (self + cross + MLP, whisper decoder); FFN is dense MLP, MoE, or
    MoE+dense residual (arctic);
  - enc-dec archs run a separate bidirectional encoder scan over precomputed
    frame embeddings (modality frontend is a stub per the brief);
  - PIM/NB-LDPC protection (the paper's technique) plugs in via `pim_ctx`:
    target projections route through the protected quantized-MAC path.

Entry points: init_params / param_axes / forward / loss_fn / init_caches /
prefill / decode_step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.distributed.sharding import constrain
from repro.nn import layers as L
from repro.nn import moe as M
from repro.nn import mamba as S
from repro.nn.kv_source import KVSource
from repro.nn.layers import CDT

# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------


def _init_block(key, spec: LayerSpec, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"norm1": {"scale": jnp.ones((cfg.d_model,), jnp.float32)}}
    if spec.kind == "mamba":
        p["mamba"] = S.init_mamba(ks[0], cfg)
    elif spec.kind == "encdec":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["norm_x"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
        p["xattn"] = L.init_attention(ks[1], cfg)
    else:
        p["attn"] = L.init_attention(ks[0], cfg)

    has_ffn = spec.moe or (cfg.d_ff > 0 and spec.kind != "encdec_noffn")
    if spec.kind == "mamba" and cfg.d_ff == 0 and not spec.moe:
        has_ffn = False
    if has_ffn:
        p["norm2"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
        if spec.moe:
            p["moe"] = M.init_moe(ks[2], cfg, cfg.expert_d_ff or cfg.d_ff)
            if spec.dense_residual:
                p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff)
        else:
            p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    if spec.kind == "encdec":
        p["norm2"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    return p


def _block_axes(spec: LayerSpec, cfg: ArchConfig):
    """Logical sharding axes parallel to _init_block's tree."""
    norm = {"scale": (None,)}
    attn = {"wq": ("fsdp", "heads_flat"), "wk": ("fsdp", "kv_flat"),
            "wv": ("fsdp", "kv_flat"), "wo": ("heads_flat", "fsdp")}
    mlp = {"w_gate": ("fsdp", "d_ff"), "w_up": ("fsdp", "d_ff"),
           "w_down": ("d_ff", "fsdp")}
    a: dict[str, Any] = {"norm1": norm}
    if spec.kind == "mamba":
        ma = S.mamba_param_axes()
        ma = {k: tuple("fsdp" if ax == "d_model" else ax for ax in v)
              for k, v in ma.items()}
        a["mamba"] = ma
    elif spec.kind == "encdec":
        a["attn"] = attn
        a["norm_x"] = norm
        a["xattn"] = attn
    else:
        a["attn"] = attn
    has_ffn = spec.moe or cfg.d_ff > 0
    if spec.kind == "mamba" and cfg.d_ff == 0 and not spec.moe:
        has_ffn = False
    if has_ffn:
        a["norm2"] = norm
        if spec.moe:
            a["moe"] = {"router": ("fsdp", None),
                        "w_gate": ("expert", "fsdp", None),
                        "w_up": ("expert", "fsdp", None),
                        "w_down": ("expert", None, "fsdp")}
            if spec.dense_residual:
                a["mlp"] = mlp
        else:
            a["mlp"] = mlp
    if spec.kind == "encdec":
        a["norm2"] = norm
        a["mlp"] = mlp
    return a


def init_params(key, cfg: ArchConfig):
    keys = jax.random.split(key, 4 + len(cfg.group_spec))
    s = 0.02
    params: dict[str, Any] = {
        "embed": s * jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model),
                                       jnp.float32),
        "final_norm": {"scale": jnp.ones((cfg.d_model,), jnp.float32)},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = s * jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), jnp.float32)

    def stack_init(key, spec, n):
        ks = jax.random.split(key, n)
        return jax.vmap(lambda k: _init_block(k, spec, cfg))(ks)

    params["groups"] = {
        f"pos{i}": stack_init(keys[4 + i], spec, cfg.n_groups)
        for i, spec in enumerate(cfg.group_spec)
    }
    if cfg.encoder_groups > 0:
        enc_spec = LayerSpec(kind="attn")   # bidirectional handled at apply
        params["encoder"] = stack_init(keys[2], enc_spec, cfg.encoder_groups)
        params["enc_norm"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    return params


def param_axes(cfg: ArchConfig):
    axes: dict[str, Any] = {
        "embed": ("vocab", "fsdp"),
        "final_norm": {"scale": (None,)},
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("fsdp", "vocab")

    def stacked(tree):
        return jax.tree.map(lambda ax: (None,) + ax, tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    axes["groups"] = {
        f"pos{i}": stacked(_block_axes(spec, cfg))
        for i, spec in enumerate(cfg.group_spec)
    }
    if cfg.encoder_groups > 0:
        axes["encoder"] = stacked(_block_axes(LayerSpec(kind="attn"), cfg))
        axes["enc_norm"] = {"scale": (None,)}
    return axes


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _ffn(bp, spec: LayerSpec, cfg: ArchConfig, h, pim_ctx):
    if spec.moe:
        y = M.moe_apply(bp["moe"], h, cfg)
        if spec.dense_residual:
            y = y + L.mlp_apply(bp["mlp"], h, cfg.act, pim_ctx=pim_ctx)
        return y
    return L.mlp_apply(bp["mlp"], h, cfg.act, pim_ctx=pim_ctx)


def _cross_kv(bp_attn, aux, cfg: ArchConfig):
    """Compute cross-attention K/V from aux embeddings (B, Na, d)."""
    B, Na, _ = aux.shape
    aux = aux.astype(CDT)
    k = (aux @ bp_attn["wk"].astype(CDT)).reshape(B, Na, cfg.n_kv_heads,
                                                  cfg.head_dim)
    v = (aux @ bp_attn["wv"].astype(CDT)).reshape(B, Na, cfg.n_kv_heads,
                                                  cfg.head_dim)
    return k, v


def _apply_block(bp, x, spec: LayerSpec, cfg: ArchConfig, *, positions,
                 aux=None, cache=None, cache_pos=None, pim_ctx=None):
    """One block. Returns (x, new_cache)."""
    new_cache: dict[str, Any] = {}
    if spec.kind == "mamba":
        state = None
        decode = cache is not None
        if decode:
            state = S.MambaState(cache["conv"], cache["ssm"])
        y, st = S.mamba_apply(bp["mamba"], L.rmsnorm(bp["norm1"], x, cfg.norm_eps),
                              cfg, state=state, decode=decode)
        x = x + y
        new_cache = {"conv": st.conv, "ssm": st.ssm}
    elif spec.kind == "encdec":
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        kv = {"k": cache["k"], "v": cache["v"]} if cache is not None else None
        y, nc = L.attention_apply(bp["attn"], h, LayerSpec(kind="attn"), cfg,
                                  positions=positions, kv_cache=kv,
                                  cache_pos=cache_pos, pim_ctx=pim_ctx)
        x = x + y
        if nc is not None:
            new_cache.update(nc)
        hx = L.rmsnorm(bp["norm_x"], x, cfg.norm_eps)
        if cache is not None and "ck" in cache:
            aux_kv = (cache["ck"], cache["cv"])
        else:
            aux_kv = _cross_kv(bp["xattn"], aux, cfg)
        yx, _ = L.attention_apply(bp["xattn"], hx,
                                  LayerSpec(kind="attn", cross=True), cfg,
                                  positions=positions, aux_kv=aux_kv,
                                  pim_ctx=pim_ctx)
        x = x + yx
        if cache is not None:
            new_cache["ck"], new_cache["cv"] = aux_kv
    else:
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        if spec.cross:
            if cache is not None and "ck" in cache:
                aux_kv = (cache["ck"], cache["cv"])
            else:
                aux_kv = _cross_kv(bp["attn"], aux, cfg)
            y, _ = L.attention_apply(bp["attn"], h, spec, cfg,
                                     positions=positions, aux_kv=aux_kv,
                                     pim_ctx=pim_ctx)
            if cache is not None:
                new_cache["ck"], new_cache["cv"] = aux_kv
        else:
            kv = None
            if cache is not None:
                # a KVSource cache (ProtectedKVLayer / the engine's batched
                # layers) routes the layer through the protected paged read
                # path; plain dicts are dense {"k","v"} decode caches (the
                # legacy {"paged": ...} dict still passes through, and
                # attention_apply warns + unwraps it)
                kv = (cache if isinstance(cache, KVSource) or "paged" in cache
                      else {"k": cache["k"], "v": cache["v"]})
            y, nc = L.attention_apply(bp["attn"], h, spec, cfg,
                                      positions=positions, kv_cache=kv,
                                      cache_pos=cache_pos, pim_ctx=pim_ctx)
            if nc is not None:
                new_cache.update(nc)
        x = x + y

    if "norm2" in bp:
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
        x = x + _ffn(bp, spec, cfg, h, pim_ctx)
    return constrain(x, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# group iteration: lax.scan (production) or Python loop (cost lowerings —
# static HLO analysis counts a `while` body once, so true FLOP/byte counts
# need the unrolled graph; used only at n_groups <= 2)
# ---------------------------------------------------------------------------


def _remat(cfg, body):
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(body, policy=pol)
    return jax.checkpoint(body)


def _iter_groups(cfg: ArchConfig, body, carry, xs, n: int):
    """scan-compatible: body(carry, xs_slice) -> (carry, ys_slice)."""
    if not cfg.unroll_groups:
        if cfg.remat:
            body = _remat(cfg, body)
        return jax.lax.scan(body, carry, xs)
    ys = []
    b = _remat(cfg, body) if cfg.remat else body
    for g in range(n):
        carry, y = b(carry, jax.tree.map(lambda t, g=g: t[g], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# encoder (whisper) — bidirectional scan over precomputed frame embeddings
# ---------------------------------------------------------------------------


def _run_encoder(params, cfg: ArchConfig, aux):
    positions = jnp.arange(aux.shape[1])

    def body(x, bp):
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        y = L.encoder_attention_apply(bp["attn"], h, cfg, positions)
        x = x + y.astype(CDT)
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(bp["mlp"], h, cfg.act)
        return constrain(x, "batch", None, None), None

    x, _ = _iter_groups(cfg, body, aux.astype(CDT), params["encoder"],
                        cfg.encoder_groups)
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# forward (training / prefill without caches)
# ---------------------------------------------------------------------------


def forward(params, cfg: ArchConfig, tokens, *, aux=None, pim_ctx=None):
    """tokens: (B, S) int32; aux: (B, Na, d_model) modality embeddings.
    Returns logits (B, S, V) float32."""
    B, Stok = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(CDT)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, CDT)
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(Stok)

    enc_out = None
    if cfg.encoder_groups > 0:
        enc_out = _run_encoder(params, cfg, aux)
        aux = enc_out                      # decoder cross-attends encoder out

    def body(x, gp):
        for i, spec in enumerate(cfg.group_spec):
            x, _ = _apply_block(gp[f"pos{i}"], x, spec, cfg,
                                positions=positions, aux=aux, pim_ctx=pim_ctx)
        return x, None

    x, _ = _iter_groups(cfg, body, x, params["groups"], cfg.n_groups)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head.astype(CDT)).astype(jnp.float32)
    if cfg.softcap_final:
        logits = cfg.softcap_final * jnp.tanh(logits / cfg.softcap_final)
    return constrain(logits, "batch", None, "vocab")


def loss_fn(params, cfg: ArchConfig, batch, *, pim_ctx=None):
    """Causal-LM cross entropy. batch: tokens (B,S), labels (B,S) with -1 =
    ignore; optional aux."""
    logits = forward(params, cfg, batch["tokens"], aux=batch.get("aux"),
                     pim_ctx=pim_ctx)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    tot = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / tot


# ---------------------------------------------------------------------------
# caches: init / prefill / decode
# ---------------------------------------------------------------------------


def _block_cache(spec: LayerSpec, cfg: ArchConfig, batch: int, max_seq: int,
                 n_aux: int):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if spec.kind == "mamba":
        return {"conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), CDT),
                "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32)}
    c: dict[str, Any] = {}
    if spec.kind == "encdec" or not spec.cross:
        seq = max_seq
        if spec.local_window:
            seq = min(max_seq, spec.local_window)
        c["k"] = jnp.zeros((batch, seq, hkv, dh), CDT)
        c["v"] = jnp.zeros((batch, seq, hkv, dh), CDT)
    if spec.kind == "encdec" or spec.cross:
        c["ck"] = jnp.zeros((batch, n_aux, hkv, dh), CDT)
        c["cv"] = jnp.zeros((batch, n_aux, hkv, dh), CDT)
    return c


def init_caches(cfg: ArchConfig, batch: int, max_seq: int, *,
                protected_kv=None):
    """Stacked (over n_groups) cache pytree for decoding.

    With `protected_kv` (a `repro.models.kv.ProtectedKVConfig`), returns a
    `ProtectedKVCaches` manager instead: global self-attention K/V lives in
    device-resident NB-LDPC-protected paged stores (quantize + encode on
    append, decode-overlapped reads), everything else stays dense. Serve it
    through the same `prefill`/`decode_step` entry points (the decode group
    loop runs unrolled in Python for that path — the paged stores are host
    objects, not scan carries).
    """
    if protected_kv is not None:
        from .kv import ProtectedKVCaches
        return ProtectedKVCaches(cfg, protected_kv, batch, max_seq)
    n_aux = cfg.n_aux_tokens or 1

    def rep(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_groups,) + x.shape).copy(), tree)

    return {f"pos{i}": rep(_block_cache(spec, cfg, batch, max_seq, n_aux))
            for i, spec in enumerate(cfg.group_spec)}


def cache_axes(cfg: ArchConfig):
    """Logical sharding axes for the cache pytree (parallel structure)."""
    def ax_block(spec: LayerSpec):
        if spec.kind == "mamba":
            return {"conv": (None, "batch", None, "d_inner"),
                    "ssm": (None, "batch", "d_inner", None)}
        c = {}
        if spec.kind == "encdec" or not spec.cross:
            c["k"] = (None, "batch", "kv_seq", "kv_heads", None)
            c["v"] = (None, "batch", "kv_seq", "kv_heads", None)
        if spec.kind == "encdec" or spec.cross:
            c["ck"] = (None, "batch", None, "kv_heads", None)
            c["cv"] = (None, "batch", None, "kv_heads", None)
        return c

    return {f"pos{i}": ax_block(spec) for i, spec in enumerate(cfg.group_spec)}


def _head_logits(params, cfg: ArchConfig, x):
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head.astype(CDT)).astype(jnp.float32)
    if cfg.softcap_final:
        logits = cfg.softcap_final * jnp.tanh(logits / cfg.softcap_final)
    return constrain(logits, "batch", None, "vocab")


def _decode_step_protected(params, cfg: ArchConfig, caches, token, pos, *,
                           aux=None, pim_ctx=None):
    """One-token decode against `ProtectedKVCaches`: the group stack runs
    unrolled in Python (paged stores are host-managed objects, not scan
    carries); each protected attention layer appends the token's K/V into
    its paged store and reads through the overlap-decode pipeline. Dense
    entries (mamba / cross / sliding-window) update in the manager.

    `pos` is a () scalar (every row at the same position) or a (B,) vector
    (the multi-tenant serving engine: ragged per-slot positions)."""
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(CDT)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, CDT)
    positions = jnp.broadcast_to(
        jnp.reshape(jnp.asarray(pos, jnp.int32), (-1, 1)), (B, 1))
    for g in range(cfg.n_groups):
        gp = jax.tree.map(lambda t, g=g: t[g], params["groups"])
        for i, spec in enumerate(cfg.group_spec):
            x, nc = _apply_block(gp[f"pos{i}"], x, spec, cfg,
                                 positions=positions, aux=aux,
                                 cache=caches.view(g, i), cache_pos=pos,
                                 pim_ctx=pim_ctx)
            caches.update(g, i, nc)
    return _head_logits(params, cfg, x), caches


def decode_step(params, cfg: ArchConfig, caches, token, pos, *, aux=None,
                pim_ctx=None):
    """One-token decode. token: (B, 1) int32; pos: () int32 current position.
    caches: stacked pytree from init_caches (cross entries must be filled by
    prefill, or `aux` provided to compute them on the fly), the
    `ProtectedKVCaches` manager from `init_caches(..., protected_kv=...)`,
    or any manager exposing the same view/update surface with
    `is_protected_manager = True` (the serving engine's batched caches,
    which also accept a (B,) per-slot `pos`).
    Returns (logits (B, 1, V), new_caches)."""
    from .kv import ProtectedKVCaches
    if (isinstance(caches, ProtectedKVCaches)
            or getattr(caches, "is_protected_manager", False)):
        return _decode_step_protected(params, cfg, caches, token, pos,
                                      aux=aux, pim_ctx=pim_ctx)
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(CDT)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, CDT)
    positions = jnp.full((B, 1), pos, jnp.int32)

    def body(x, xs):
        gp, gc = xs
        new_c = {}
        for i, spec in enumerate(cfg.group_spec):
            x, nc = _apply_block(gp[f"pos{i}"], x, spec, cfg,
                                 positions=positions, aux=aux,
                                 cache=gc[f"pos{i}"], cache_pos=pos,
                                 pim_ctx=pim_ctx)
            new_c[f"pos{i}"] = nc
        return x, new_c

    import dataclasses as _dc
    cfg_nr = _dc.replace(cfg, remat=False)      # no remat in inference steps
    x, new_caches = _iter_groups(cfg_nr, body, x, (params["groups"], caches),
                                 cfg.n_groups)
    return _head_logits(params, cfg, x), new_caches


def prefill(params, cfg: ArchConfig, tokens, *, aux=None, pim_ctx=None,
            protected_kv=None, max_seq: int | None = None):
    """Run the full prompt, building decode caches. Returns (logits, caches).

    The sequence axis is processed in full (scored prompt); caches are filled
    by scattering K/V at all positions (self-attn) and computing cross K/V /
    final mamba state.

    With `protected_kv` (a `repro.models.kv.ProtectedKVConfig`), the dense
    prompt caches are ingested into a `ProtectedKVCaches` manager — prompt
    K/V quantized and device-encoded page by page — and that manager is
    returned instead (`max_seq` sizes the dense non-protected entries;
    defaults to the prompt length).
    """
    B, Stok = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(CDT)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, CDT)
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(Stok)

    enc_out = None
    if cfg.encoder_groups > 0:
        enc_out = _run_encoder(params, cfg, aux)
        aux = enc_out

    hkv, dh = cfg.n_kv_heads, cfg.head_dim

    def body(x, gp):
        caches = {}
        for i, spec in enumerate(cfg.group_spec):
            cache_entry: dict[str, Any] = {}
            if spec.kind == "mamba":
                h = L.rmsnorm(gp[f"pos{i}"]["norm1"], x, cfg.norm_eps)
                y, st = S.mamba_apply(gp[f"pos{i}"]["mamba"], h, cfg)
                x = x + y
                if "norm2" in gp[f"pos{i}"]:
                    h2 = L.rmsnorm(gp[f"pos{i}"]["norm2"], x, cfg.norm_eps)
                    x = x + _ffn(gp[f"pos{i}"], spec, cfg, h2, pim_ctx)
                cache_entry = {"conv": st.conv, "ssm": st.ssm}
                x = constrain(x, "batch", None, None)
            else:
                bp = gp[f"pos{i}"]
                # capture K/V by recomputing projections (cheap vs attention)
                h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
                if spec.kind == "encdec" or not spec.cross:
                    k = (h @ bp["attn"]["wk"].astype(CDT)).reshape(
                        B, Stok, hkv, dh)
                    v = (h @ bp["attn"]["wv"].astype(CDT)).reshape(
                        B, Stok, hkv, dh)
                    k = L.rope(k, positions, cfg.rope_theta)
                    if spec.local_window and spec.local_window < Stok:
                        # ring-buffer alignment: token at absolute position q
                        # must sit at slot q % W (decode writes at pos % W)
                        Wd = spec.local_window
                        k = jnp.roll(k[:, -Wd:], Stok % Wd, axis=1)
                        v = jnp.roll(v[:, -Wd:], Stok % Wd, axis=1)
                    cache_entry["k"] = k
                    cache_entry["v"] = v
                if spec.kind == "encdec" or spec.cross:
                    attn_p = bp["xattn"] if spec.kind == "encdec" else bp["attn"]
                    ck, cv = _cross_kv(attn_p, aux, cfg)
                    cache_entry["ck"], cache_entry["cv"] = ck, cv
                x, _ = _apply_block(bp, x, spec, cfg, positions=positions,
                                    aux=aux, pim_ctx=pim_ctx)
            caches[f"pos{i}"] = cache_entry
        return x, caches

    x, caches = _iter_groups(cfg, body, x, params["groups"], cfg.n_groups)
    logits = _head_logits(params, cfg, x)
    if protected_kv is not None:
        from .kv import ProtectedKVCaches
        pkv_caches = ProtectedKVCaches(cfg, protected_kv, B,
                                       max_seq or Stok)
        pkv_caches.ingest_prefill(caches, Stok)
        return logits, pkv_caches
    return logits, caches


# ---------------------------------------------------------------------------
# PIM deployment: precoded weights (paper's deploy-time encode, Fig. 2(b))
# ---------------------------------------------------------------------------


def encode_params_for_pim(params, cfg: ArchConfig):
    """Deploy-time transform: for every protected projection, store the
    ternarized + NB-LDPC-encoded int8 weights (and the ternary scale) next
    to the fp weights. Serving then reads only the encoded integers — the
    paper's 'write-time encode': checks are generated when the array is
    programmed, not per MAC."""
    from repro.core.context import PIMContext
    ctx = PIMContext(cfg.pim)
    targets = set(cfg.pim.targets)

    def enc_block(bp):
        bp = dict(bp)
        if "mlp" in bp and "mlp_down" in targets:
            mlp = dict(bp["mlp"])
            e, a = jax.vmap(ctx.encode_weight)(mlp["w_down"])
            mlp["w_down_enc"], mlp["w_down_alpha"] = e, a
            bp["mlp"] = mlp
        if "attn" in bp and "attn_o" in targets:
            at = dict(bp["attn"])
            e, a = jax.vmap(ctx.encode_weight)(at["wo"])
            at["wo_enc"], at["wo_alpha"] = e, a
            bp["attn"] = at
        return bp

    params = dict(params)
    params["groups"] = {k: enc_block(v) for k, v in params["groups"].items()}
    return params


def pim_param_axes(axes, cfg: ArchConfig):
    """Logical axes for the encoded leaves (parallel to
    encode_params_for_pim). Check columns ride inside each codeword block,
    so the column dim stays unsharded — decode is shard-local (DESIGN §3)."""
    targets = set(cfg.pim.targets)

    def upd(block):
        block = dict(block)
        if "mlp" in block and "mlp_down" in targets:
            m = dict(block["mlp"])
            m["w_down_enc"] = (None, "d_ff", None)
            m["w_down_alpha"] = (None,)
            block["mlp"] = m
        if "attn" in block and "attn_o" in targets:
            a = dict(block["attn"])
            a["wo_enc"] = (None, "heads_flat", None)
            a["wo_alpha"] = (None,)
            block["attn"] = a
        return block

    axes = dict(axes)
    axes["groups"] = {k: upd(v) for k, v in axes["groups"].items()}
    return axes
