"""Config-driven model stacks (decoder-only / enc-dec / hybrid / SSM)."""
from .lm import (init_params, param_axes, forward, loss_fn, init_caches,
                 cache_axes, decode_step, prefill, encode_params_for_pim,
                 pim_param_axes)
from .kv import ProtectedKVConfig, ProtectedKVLayer, ProtectedKVCaches
