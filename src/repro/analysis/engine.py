"""AST rule engine for the codebase-aware static lint pass.

`run_paths(paths)` walks the given files/directories, parses each `*.py`
once, and runs every registered rule (see `repro.analysis.rules`) over the
shared `FileContext`. Violations come back as `Diagnostic`s unless the
flagged line carries a `# noqa` comment — bare `# noqa` suppresses every
code on that line, `# noqa: RPL003` (comma-separated for several) just the
listed ones. Suppressions must be justified: the repo policy is one short
trailing comment per noqa saying why the rule does not apply.

Rules register through the `rule(...)` decorator into `RULES`; each rule is
a generator over `(node, message)` pairs. The engine owns path/line/col
bookkeeping, noqa filtering, and `--select` subsetting so rules stay pure
AST logic.
"""
from __future__ import annotations

import ast
import os
import re
from collections.abc import Callable, Iterable, Iterator

from .diagnostics import Diagnostic

__all__ = ["FileContext", "Rule", "RULES", "rule", "run_file", "run_paths",
           "iter_py_files"]

# bare `# noqa` (all codes) or `# noqa: RPL001, RPL004` (listed codes)
_NOQA_RE = re.compile(
    r"#\s*noqa\b"
    r"(?::\s*(?P<codes>[A-Z]{2,4}\d{3}(?:[,\s]+[A-Z]{2,4}\d{3})*))?",
    re.IGNORECASE)


class Rule:
    """One registered check: a stable RPL code plus a pure-AST generator."""

    def __init__(self, code: str, name: str, summary: str,
                 check: Callable[["FileContext"], Iterator]):
        self.code = code
        self.name = name
        self.summary = summary
        self.check = check

    def __repr__(self):
        return f"Rule({self.code} {self.name})"


RULES: dict[str, Rule] = {}


def rule(code: str, name: str, summary: str):
    """Register a rule function under `code`. The function takes a
    `FileContext` and yields `(ast.AST node, message str)` pairs."""
    def deco(fn):
        if code in RULES:
            raise ValueError(f"duplicate rule code {code}")
        RULES[code] = Rule(code, name, summary, fn)
        return fn
    return deco


def _parse_noqa(lines: list[str]) -> dict[int, frozenset | None]:
    """line number (1-indexed) -> None (bare noqa: all codes) or the
    frozenset of suppressed codes."""
    out: dict[int, frozenset | None] = {}
    for i, text in enumerate(lines, start=1):
        if "noqa" not in text:
            continue
        m = _NOQA_RE.search(text)
        if not m:
            continue
        codes = m.group("codes")
        if codes is None:
            out[i] = None
        else:
            out[i] = frozenset(c.strip().upper()
                               for c in re.split(r"[,\s]+", codes) if c)
    return out


def _collect_imports(tree: ast.Module) -> dict[str, str]:
    """Local name -> dotted origin ("np" -> "numpy", "ss" ->
    "repro.kernels.ops.scan_syndromes"). Relative imports keep their
    leading dots so callers can still pattern-match the tail."""
    imports: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                imports[alias.asname or alias.name.split(".")[0]] = alias.name
        elif isinstance(node, ast.ImportFrom):
            base = "." * node.level + (node.module or "")
            for alias in node.names:
                if alias.name == "*":
                    continue
                origin = f"{base}.{alias.name}" if base else alias.name
                imports[alias.asname or alias.name] = origin
    return imports


class FileContext:
    """Everything a rule needs about one parsed file."""

    def __init__(self, path: str, source: str):
        self.path = path.replace(os.sep, "/")
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        self.noqa = _parse_noqa(self.lines)
        self.imports = _collect_imports(self.tree)
        self._parents: dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent

    def parent(self, node: ast.AST) -> ast.AST | None:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def dotted(self, node: ast.AST) -> str | None:
        """Textual dotted name of a Name/Attribute chain ("np.random.rng"),
        or None for anything that is not a plain chain."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        return ".".join(reversed(parts))

    def qualname(self, node: ast.AST) -> str | None:
        """`dotted()` with the leading segment resolved through this file's
        imports: `jnp.dot` -> "jax.numpy.dot"."""
        text = self.dotted(node)
        if text is None:
            return None
        head, _, rest = text.partition(".")
        origin = self.imports.get(head, head)
        return f"{origin}.{rest}" if rest else origin

    def suppressed(self, code: str, line: int) -> bool:
        if line not in self.noqa:
            return False
        codes = self.noqa[line]
        return codes is None or code in codes


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                yield path
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(d for d in dirs
                             if not d.startswith(".") and d != "__pycache__")
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def run_file(path: str, select: Iterable[str] | None = None
             ) -> list[Diagnostic]:
    from . import rules as _rules  # noqa: F401  # registers the rule set

    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    try:
        ctx = FileContext(path, source)
    except SyntaxError as e:
        return [Diagnostic("RPL000", f"syntax error: {e.msg}",
                           path.replace(os.sep, "/"), e.lineno or 1,
                           (e.offset or 1) - 1, "parse-error")]
    wanted = None if select is None else {c.upper() for c in select}
    out: list[Diagnostic] = []
    for r in RULES.values():
        if wanted is not None and r.code not in wanted:
            continue
        for node, message in r.check(ctx):
            line = getattr(node, "lineno", 1)
            col = getattr(node, "col_offset", 0)
            if ctx.suppressed(r.code, line):
                continue
            out.append(Diagnostic(r.code, message, ctx.path, line, col,
                                  r.name))
    out.sort(key=lambda d: (d.path, d.line, d.col, d.code))
    return out


def run_paths(paths: Iterable[str], select: Iterable[str] | None = None
              ) -> tuple[list[Diagnostic], int]:
    """Run every (selected) rule over the python files under `paths`.
    Returns (diagnostics, files_scanned)."""
    from . import rules as _rules  # noqa: F401  # registers the rule set

    diags: list[Diagnostic] = []
    n_files = 0
    for path in iter_py_files(paths):
        n_files += 1
        diags.extend(run_file(path, select=select))
    return diags, n_files
