"""repro.analysis — codebase-aware static lint pass + runtime sanitizer.

Two halves, one contract: the invariants ruff cannot see.

- **Static pass** (`python -m repro.analysis src benchmarks tests`): AST
  rules with stable `RPL###` codes over the repo's own conventions —
  kernel-policy hygiene, GF accumulator-bound guards, trace purity,
  jit-cache hygiene, the telemetry allocation-free-when-disabled contract,
  and removed-API detection. `# noqa: RPL###` suppresses a finding on its
  line (with a justification comment, per repo policy).
- **Runtime sanitizer** (`use_sanitizer`): `jax.checkify` assertions on the
  GF/attention entry points (symbols in `[0, p)`, finite attention
  accumulators, sane quantization scales) so tests can turn silent
  arithmetic corruption into hard errors:

      from repro.analysis import use_sanitizer
      with use_sanitizer():
          store.append_words(w)      # raises on out-of-range symbols

The static half is stdlib-only (no jax import); sanitizer names are
lazily re-exported so `python -m repro.analysis` stays fast.
"""
from repro.analysis.diagnostics import Diagnostic
from repro.analysis.engine import RULES, FileContext, run_file, run_paths

_SANITIZER_NAMES = ("use_sanitizer", "sanitizer_enabled", "check_gf_symbols",
                    "check_finite", "check_quant_scales", "SanitizerError")

__all__ = ["Diagnostic", "FileContext", "RULES", "run_file", "run_paths",
           *_SANITIZER_NAMES]


def __getattr__(name):
    if name in _SANITIZER_NAMES:
        from repro.analysis import sanitizer
        return getattr(sanitizer, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
