"""CLI: `python -m repro.analysis [paths...] [--json] [--select RPL001,...]`.

Exits nonzero when any diagnostic is emitted — the CI `analysis` job runs
`python -m repro.analysis src benchmarks tests` and fails on any finding.
"""
from __future__ import annotations

import argparse
import json
import sys

from .engine import RULES, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Codebase-aware static lint pass for the GF/Pallas "
                    "stack (RPL### rules; suppress with `# noqa: RPL###`).")
    ap.add_argument("paths", nargs="*", default=["src", "benchmarks",
                                                 "tests"],
                    help="files or directories to scan (default: src "
                         "benchmarks tests)")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON instead of text")
    ap.add_argument("--select", default=None,
                    help="comma-separated RPL codes to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        from . import rules as _rules  # noqa: F401  # registers the rules
        for code in sorted(RULES):
            r = RULES[code]
            print(f"{code}  {r.name:<24} {r.summary}")
        return 0

    select = None
    if args.select:
        select = [c.strip().upper() for c in args.select.split(",") if c]
    diags, n_files = run_paths(args.paths, select=select)

    if args.json:
        json.dump({"files_scanned": n_files,
                   "diagnostics": [d.to_json() for d in diags]},
                  sys.stdout, indent=2)
        print()
    else:
        for d in diags:
            print(d.format())
        noun = "diagnostic" if len(diags) == 1 else "diagnostics"
        print(f"{len(diags)} {noun} ({n_files} files scanned)")
    return 1 if diags else 0


if __name__ == "__main__":
    sys.exit(main())
