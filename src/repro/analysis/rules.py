"""Codebase-specific rules for the GF/Pallas stack.

Each rule encodes an invariant the generic linters cannot see:

- **RPL001 kernel-policy-hygiene** — literal `interpret=` booleans outside
  `kernels/backend.py`. PR 7 shipped a hardcoded `interpret=True` default in
  `flash_attention.py` that silently interpreted on TPU; mode selection must
  route through `KernelPolicy` / `use_policy` / `resolve_interpret`.
- **RPL002 overflow-bound-guard** — direct calls to the GF kernel entry
  points (`scan_syndromes`, `gf_matmul`, `encode_words` from
  `repro.kernels.ops`, or any raw `*_pallas` kernel) outside
  `src/repro/kernels/` without a reachable `K*(p-1)**2` accumulator-bound
  guard in the enclosing function/class. The int32 kernel accumulator wraps
  silently past `n*(p-1)^2 >= 2^31` (float32 host BLAS past `2^24`).
- **RPL003 trace-purity** — impure Python inside `jax.jit` /
  `pl.pallas_call` targets: stdlib `random`/`time`, `np.random`, `.item()`
  coercion, `float()`/`bool()`/`int()` on traced parameters, mutable
  default arguments. These either leak host state into a cached trace or
  force device sync.
- **RPL004 jit-cache-hygiene** — `jax.jit(...)` constructed inside a loop,
  invoked immediately (`jax.jit(f)(x)`), or built per-call in a method with
  no cache write: every such construction retraces from scratch.
- **RPL005 telemetry-hot-path** — instrument calls (`counter`/`gauge`/
  `histogram` factories, `observe_scan`/`observe_decode`, `.instant`) in
  the hot-path packages (`memory/`, `serving/`, `models/`, `core/`) must
  sit behind an `.enabled` read, per the `repro.obs` null-singleton design
  ("allocation-free when disabled").
- **RPL006 deprecated-api** — the removed `backend=`/`scan_backend=`
  constructor kwargs and the legacy `{"paged": ...}` dict KV routing.
- **RPL007 host-sync-in-loop** — `np.asarray(...)` / `jax.device_get(...)` /
  `.item()` inside a `for`/`while`/comprehension in the `memory/` and
  `serving/` hot paths. One host sync per iteration serializes device
  dispatch (the per-page repair bottleneck the coalescing pipeline fixes):
  launch every iteration's device work first, then resolve once. Justified
  drain points (e.g. the pipeline's windowed sync) carry `# noqa: RPL007`.

Rules yield `(node, message)`; the engine handles noqa and reporting.
"""
from __future__ import annotations

import ast

from .engine import FileContext, rule

# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------

_JIT_WRAPPERS = ("jax.jit", "jit", "jax.pmap", "pmap")
_PALLAS_WRAPPERS = ("jax.experimental.pallas.pallas_call", "pallas_call")
_PARTIAL = ("functools.partial", "partial")

_SCOPES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
_LOOPS = (ast.For, ast.AsyncFor, ast.While, ast.ListComp, ast.SetComp,
          ast.DictComp, ast.GeneratorExp)


def _is_wrapper(ctx: FileContext, func: ast.AST, names) -> bool:
    qn = ctx.qualname(func)
    return qn in names if qn is not None else False


def _enclosing_function(ctx: FileContext, node: ast.AST):
    for anc in ctx.ancestors(node):
        if isinstance(anc, _SCOPES):
            return anc
    return None


def _static_argnames(call: ast.Call) -> frozenset:
    """static_argnames=("p", ...) parsed off a partial(jax.jit, ...) call."""
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums") and \
                isinstance(kw.value, (ast.Tuple, ast.List)):
            return frozenset(e.value for e in kw.value.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
        if kw.arg == "static_argnames" and isinstance(kw.value, ast.Constant):
            return frozenset([kw.value.value])
    return frozenset()


def _defs_by_name(ctx: FileContext) -> dict:
    out: dict[str, list] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, []).append(node)
    return out


def _jit_targets(ctx: FileContext) -> dict:
    """Function/Lambda nodes that become jax traces -> frozenset of
    statically-bound parameter names (never tracers inside the body)."""
    targets: dict[ast.AST, frozenset] = {}
    defs = _defs_by_name(ctx)

    def mark(fn_node, statics):
        if fn_node is not None:
            targets[fn_node] = targets.get(fn_node, frozenset()) | statics

    def mark_ref(arg, statics):
        if isinstance(arg, ast.Lambda):
            mark(arg, statics)
        elif isinstance(arg, ast.Name):
            for fn in defs.get(arg.id, ()):
                mark(fn, statics)
        elif isinstance(arg, ast.Call) and \
                _is_wrapper(ctx, arg.func, _PARTIAL) and arg.args:
            mark_ref(arg.args[0], statics)

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_wrapper(ctx, dec, _JIT_WRAPPERS + _PALLAS_WRAPPERS):
                    mark(node, frozenset())
                elif isinstance(dec, ast.Call):
                    if _is_wrapper(ctx, dec.func,
                                   _JIT_WRAPPERS + _PALLAS_WRAPPERS):
                        mark(node, _static_argnames(dec))
                    elif _is_wrapper(ctx, dec.func, _PARTIAL) and dec.args \
                            and _is_wrapper(ctx, dec.args[0], _JIT_WRAPPERS):
                        mark(node, _static_argnames(dec))
        elif isinstance(node, ast.Call):
            if _is_wrapper(ctx, node.func, _JIT_WRAPPERS) and node.args:
                mark_ref(node.args[0], _static_argnames(node))
            elif _is_wrapper(ctx, node.func, _PALLAS_WRAPPERS) and node.args:
                mark_ref(node.args[0], frozenset())
    return targets


def _nearest_jit_target(ctx: FileContext, node: ast.AST, targets):
    if node in targets:
        return node
    for anc in ctx.ancestors(node):
        if anc in targets:
            return anc
    return None


def _param_names(fn) -> list:
    args = fn.args
    return [a.arg for a in (args.posonlyargs + args.args + args.kwonlyargs)]


# --------------------------------------------------------------------------
# RPL001 — kernel-policy hygiene
# --------------------------------------------------------------------------

@rule("RPL001", "kernel-policy-hygiene",
      "literal interpret= booleans outside kernels/backend.py")
def check_interpret_literal(ctx: FileContext):
    if ctx.path.endswith("kernels/backend.py"):
        return
    msg = ("literal `interpret={val}` pins the Pallas mode at the call site "
           "(the PR 7 flash_attention bug class); pass interpret=None and "
           "resolve through KernelPolicy/use_policy (repro.kernels.backend)")
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "interpret" and isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, bool):
                    yield kw.value, msg.format(val=kw.value.value)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
            args = node.args
            pos = args.posonlyargs + args.args
            for name, default in zip(pos[len(pos) - len(args.defaults):],
                                     args.defaults, strict=True):
                if name.arg == "interpret" and \
                        isinstance(default, ast.Constant) and \
                        isinstance(default.value, bool):
                    yield default, (
                        f"`interpret: ... = {default.value}` default "
                        "hardcodes the Pallas mode; default to None and "
                        "resolve through KernelPolicy/resolve_interpret")
            for name, default in zip(args.kwonlyargs, args.kw_defaults,
                                     strict=True):
                if name.arg == "interpret" and default is not None and \
                        isinstance(default, ast.Constant) and \
                        isinstance(default.value, bool):
                    yield default, (
                        f"`interpret: ... = {default.value}` default "
                        "hardcodes the Pallas mode; default to None and "
                        "resolve through KernelPolicy/resolve_interpret")


# --------------------------------------------------------------------------
# RPL002 — overflow-bound guards on raw GF kernel entry calls
# --------------------------------------------------------------------------

_KERNEL_ENTRIES = {"scan_syndromes", "gf_matmul", "encode_words"}
_KERNEL_MODULES = ("repro.kernels.ops", "repro.kernels")


def _bound_guard_expr(node: ast.AST) -> bool:
    """True for an expression that reads as an accumulator-bound check:
    it mentions a squared term (`(p-1)**2`) together with a `2**24`/`2**31`
    style limit, or names a *_BOUND constant."""
    has_square = has_limit = False
    for sub in ast.walk(node):
        if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Pow):
            exp = sub.right
            base = sub.left
            if isinstance(exp, ast.Constant) and exp.value == 2:
                has_square = True
            if isinstance(base, ast.Constant) and base.value == 2 and \
                    isinstance(exp, ast.Constant) and \
                    isinstance(exp.value, int) and exp.value >= 16:
                has_limit = True
        elif isinstance(sub, ast.Name) and "BOUND" in sub.id.upper():
            has_square = has_limit = True
        elif isinstance(sub, ast.Attribute) and "BOUND" in sub.attr.upper():
            has_square = has_limit = True
    return has_square and has_limit


def _guard_scope(ctx: FileContext, node: ast.AST) -> ast.AST:
    """Where a bound guard counts as reachable: the outermost enclosing
    class if any (shared helpers like `MemoryController._scan_route` guard
    for every method), else the outermost enclosing function, else the
    module."""
    best = None
    for anc in ctx.ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda, ast.ClassDef)):
            best = anc
    return best if best is not None else ctx.tree


def _scope_has_bound_guard(scope: ast.AST) -> bool:
    for sub in ast.walk(scope):
        if isinstance(sub, (ast.Assert, ast.If, ast.IfExp, ast.While)) and \
                _bound_guard_expr(sub.test):
            return True
        if isinstance(sub, ast.Compare) and _bound_guard_expr(sub):
            return True
    return False


@rule("RPL002", "overflow-bound-guard",
      "raw GF kernel entry calls without a reachable K*(p-1)**2 bound check")
def check_overflow_bounds(ctx: FileContext):
    if "repro/kernels/" in ctx.path:
        return
    guard_cache: dict[int, bool] = {}
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        qn = ctx.qualname(node.func)
        if qn is None:
            continue
        tail = qn.rsplit(".", 1)[-1]
        from_kernels = qn.startswith(_KERNEL_MODULES)
        if tail.endswith("_pallas") and from_kernels:
            yield node, (
                f"raw Pallas kernel `{tail}` called outside repro.kernels; "
                "route through the repro.kernels.ops wrapper (padding + "
                "policy resolution + accumulator-bound assert)")
            continue
        if tail in _KERNEL_ENTRIES and from_kernels:
            scope = _guard_scope(ctx, node)
            key = id(scope)
            if key not in guard_cache:
                guard_cache[key] = _scope_has_bound_guard(scope)
            if not guard_cache[key]:
                yield node, (
                    f"`{tail}` called with no reachable K*(p-1)**2 "
                    "accumulator-bound guard in the enclosing scope; the "
                    "int32 kernel accumulator wraps silently past 2**31 "
                    "(float32 BLAS past 2**24) — guard the bound or route "
                    "through MemoryController/PagedProtectedStore")


# --------------------------------------------------------------------------
# RPL003 — trace purity inside jit / pallas targets
# --------------------------------------------------------------------------

_IMPURE_MODULES = ("random", "time", "numpy.random")


@rule("RPL003", "trace-purity",
      "host-impure Python inside jax.jit / pl.pallas_call targets")
def check_trace_purity(ctx: FileContext):
    targets = _jit_targets(ctx)
    if not targets:
        return
    for fn in targets:
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
            continue
        args = fn.args
        pos = args.posonlyargs + args.args
        for name, default in list(zip(pos[len(pos) - len(args.defaults):],
                                      args.defaults, strict=True)) + \
                [(n, d) for n, d in zip(args.kwonlyargs, args.kw_defaults,
                                        strict=True) if d is not None]:
            if isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(default, ast.Call) and
                    isinstance(default.func, ast.Name) and
                    default.func.id in ("list", "dict", "set")):
                yield default, (
                    f"mutable default `{name.arg}=...` on a jitted function "
                    "is captured once at trace time and shared across calls")
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        target = _nearest_jit_target(ctx, node, targets)
        if target is None:
            continue
        qn = ctx.qualname(node.func)
        if qn is not None:
            root = qn.split(".")[0]
            mod = qn.rsplit(".", 1)[0] if "." in qn else qn
            if root in ("random", "time") and ctx.imports.get(root) == root \
                    and "." in qn:
                yield node, (
                    f"`{qn}` inside a jitted function runs on the host at "
                    "trace time only — its value is baked into the cached "
                    "trace, not refreshed per call")
                continue
            if mod.startswith("numpy.random") or qn.startswith("numpy.random"):
                yield node, (
                    f"`{qn}` inside a jitted function draws host entropy at "
                    "trace time; use jax.random with an explicit key")
                continue
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and not node.args:
            yield node, (
                "`.item()` inside a jitted function forces a host sync / "
                "concretization error on traced values")
            continue
        if isinstance(node.func, ast.Name) and \
                node.func.id in ("float", "int", "bool") and \
                len(node.args) == 1 and isinstance(node.args[0], ast.Name):
            statics = targets[target]
            params = _param_names(target)
            argname = node.args[0].id
            if argname in params and argname not in statics:
                yield node, (
                    f"`{node.func.id}({argname})` coerces a traced parameter "
                    "inside a jitted function (concretization error / "
                    "silently baked constant); hoist it out of the trace or "
                    "mark the parameter static")


# --------------------------------------------------------------------------
# RPL004 — jit-cache hygiene
# --------------------------------------------------------------------------

def _has_cache_write(fn: ast.AST) -> bool:
    """A per-call jit construction is fine when the function memoizes it:
    any assignment into an attribute or subscript (self._fn = ..., or
    cache[key] = ...) counts as the cache write."""
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign):
            targets = sub.targets
        elif isinstance(sub, (ast.AugAssign, ast.AnnAssign)):
            targets = [sub.target]
        else:
            continue
        for t in targets:
            for leaf in ast.walk(t):
                if isinstance(leaf, (ast.Attribute, ast.Subscript)):
                    return True
    return False


@rule("RPL004", "jit-cache-hygiene",
      "jax.jit constructed where every call retraces")
def check_jit_cache(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and
                _is_wrapper(ctx, node.func, ("jax.jit", "jit"))):
            continue
        parent = ctx.parent(node)
        if isinstance(parent, ast.Call) and parent.func is node:
            yield node, (
                "`jax.jit(f)(...)` constructs and traces a fresh executable "
                "on every call; build the jitted callable once and reuse it")
            continue
        in_loop = False
        for anc in ctx.ancestors(node):
            if isinstance(anc, _SCOPES):
                break
            if isinstance(anc, _LOOPS):
                in_loop = True
                break
        if in_loop:
            yield node, (
                "`jax.jit(...)` constructed inside a loop retraces every "
                "iteration; hoist the construction out of the loop")
            continue
        fn = _enclosing_function(ctx, node)
        if fn is None or isinstance(fn, ast.Lambda):
            continue
        parent_scope = ctx.parent(fn)
        is_method = isinstance(parent_scope, ast.ClassDef)
        if is_method and fn.name not in ("__init__", "__post_init__") \
                and not _has_cache_write(fn):
            yield node, (
                f"`jax.jit(...)` built per call in method `{fn.name}` with "
                "no cache write; memoize the executable (see "
                "MemoryController._decoder) or construct it in __init__")


# --------------------------------------------------------------------------
# RPL005 — telemetry hot-path contract
# --------------------------------------------------------------------------

_HOT_PACKAGES = ("repro/memory/", "repro/serving/", "repro/models/",
                 "repro/core/")
_INSTRUMENTS = {"counter", "gauge", "histogram", "observe_scan",
                "observe_decode", "instant"}


def _mentions_enabled(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "enabled":
            return True
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) and \
                sub.func.id == "getattr" and any(
                    isinstance(a, ast.Constant) and a.value == "enabled"
                    for a in sub.args):
            return True
    return False


def _early_out_guard(fn: ast.AST, before_line: int) -> bool:
    """`if not reg.enabled: return` style guard lexically before the call
    in the same function body."""
    for sub in ast.walk(fn):
        if not isinstance(sub, ast.If) or sub.lineno >= before_line:
            continue
        if not _mentions_enabled(sub.test):
            continue
        if any(isinstance(s, (ast.Return, ast.Continue, ast.Raise))
               for s in sub.body):
            return True
    return False


@rule("RPL005", "telemetry-hot-path",
      "unguarded instrument calls in the hot-path packages")
def check_telemetry_guard(ctx: FileContext):
    if not any(pkg in ctx.path for pkg in _HOT_PACKAGES):
        return
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and
                isinstance(node.func, ast.Attribute) and
                node.func.attr in _INSTRUMENTS):
            continue
        guarded = False
        for anc in ctx.ancestors(node):
            if isinstance(anc, (ast.If, ast.IfExp)) and \
                    _mentions_enabled(anc.test):
                guarded = True
                break
            if isinstance(anc, _SCOPES):
                if not isinstance(anc, ast.Lambda) and \
                        _early_out_guard(anc, node.lineno):
                    guarded = True
                break
        if not guarded:
            yield node, (
                f"instrument call `.{node.func.attr}(...)` in a hot-path "
                "package without an `.enabled` guard; the repro.obs "
                "contract is allocation-free when telemetry is off — wrap "
                "in `if reg.enabled:` (or an early-out guard)")


# --------------------------------------------------------------------------
# RPL006 — deprecated APIs
# --------------------------------------------------------------------------

_BACKEND_CTORS = {"PagedProtectedStore", "PooledStore", "ProtectedPagePool",
                  "MemoryController"}


@rule("RPL006", "deprecated-api",
      "removed backend=/scan_backend= kwargs and {'paged': ...} routing")
def check_deprecated_api(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = ctx.dotted(node.func)
        callee_tail = callee.rsplit(".", 1)[-1] if callee else ""
        for kw in node.keywords:
            if kw.arg == "scan_backend":
                yield kw.value, (
                    "`scan_backend=` was removed in PR 8; pass "
                    "`policy=` (KernelPolicy) — see "
                    "policy_from_scan_backend for the legacy mapping")
            elif kw.arg == "backend" and callee_tail in _BACKEND_CTORS:
                yield kw.value, (
                    f"`backend=` on {callee_tail} was removed in PR 8; pass "
                    "`policy=` (KernelPolicy) — see "
                    "policy_from_store_backend for the legacy mapping")
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Dict) and any(
                    isinstance(k, ast.Constant) and k.value == "paged"
                    for k in arg.keys):
                yield arg, (
                    "legacy `{'paged': layer}` dict routing is deprecated; "
                    "pass the KVSource object directly (repro.nn.kv_source)")


# --------------------------------------------------------------------------
# RPL007 — host-sync-in-loop in the memory/serving hot paths
# --------------------------------------------------------------------------

_SYNC_PATHS = ("repro/memory/", "repro/serving/")
_SYNC_FUNCS = ("numpy.asarray", "jax.device_get", "jax.block_until_ready")


@rule("RPL007", "host-sync-in-loop",
      "per-iteration host syncs in memory/ and serving/ loops")
def check_host_sync_loop(ctx: FileContext):
    if not any(pkg in ctx.path for pkg in _SYNC_PATHS):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        qn = ctx.qualname(node.func)
        if qn in _SYNC_FUNCS:
            label = qn
        elif isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and not node.args:
            label = ".item()"
        else:
            continue
        # only loops in the SAME function body count: a nested function
        # defined inside a loop (e.g. a dispatch closure) runs on its own
        # schedule, not once per iteration
        in_loop = False
        for anc in ctx.ancestors(node):
            if isinstance(anc, _SCOPES):
                break
            if isinstance(anc, _LOOPS):
                in_loop = True
                break
        if not in_loop:
            continue
        yield node, (
            f"`{label}` inside a loop forces one host sync per iteration, "
            "serializing device dispatch against the host (the per-page "
            "repair bottleneck); dispatch every iteration's device work "
            "first and resolve once (`jax.device_get` on the collected "
            "list, or RepairQueue.drain), or mark a justified drain point "
            "with `# noqa: RPL007`")
