"""Runtime sanitizer: `jax.checkify` assertions on the GF/Pallas entry
points, behind a `use_sanitizer` ambient mirroring `use_policy`.

The GF pipeline's failure mode is *silent*: an out-of-range symbol still
flows through `(y @ Ht) % p`, a NaN query poisons the online-softmax
`m/l/acc` recurrence without raising, a negative quantization scale just
flips signs. The paper's NB-LDPC scheme exists because PIM hardware has the
same property — arithmetic faults corrupt results without faulting. This
module gives the software stack hard errors instead:

    from repro.analysis import use_sanitizer
    with use_sanitizer():
        ops.scan_syndromes(y, ht, p)        # raises SanitizerError on y >= p
        ops.attend_protected(...)           # raises on non-finite output

Checks are wired into `repro.kernels.ops` (`gf_matmul`, `encode_words`,
`scan_syndromes`, `attend_protected`) and `repro.core.decode
.decode_integers` (output-side there: received words are raw arithmetic
levels that legitimately drift outside [0, p) — the decoder's *products*
carry the alphabet invariant). Each check is a cached
`jax.jit(checkify.checkify(...))`
executable, so the sanitized path stays fully device-side; when the
sanitizer is off every entry point pays exactly one module-level bool read.

Scope: checks run on *eager* entry calls — values reaching an entry point
under an enclosing `jax.jit` trace are tracers whose checkify error cannot
be thrown host-side, so they are skipped (same convention as the
`repro.obs` estimator feed in `decode_integers`). Tier-1 tests and the
benches call the entry points eagerly, which is where the sanitizer earns
its keep.
"""
from __future__ import annotations

import contextlib
import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import checkify

__all__ = ["use_sanitizer", "sanitizer_enabled", "check_gf_symbols",
           "check_finite", "check_quant_scales", "SanitizerError"]

SanitizerError = checkify.JaxRuntimeError

# REPRO_SANITIZE=1 arms the ambient at import — the CI sanitizer-smoke step
# (and any TPU-validation bench run) uses this to sweep an existing test
# subset under the checks without touching its code.
_enabled = os.environ.get("REPRO_SANITIZE", "") == "1"


def sanitizer_enabled() -> bool:
    """One cheap read per entry-point call (mirrors `registry.enabled`)."""
    return _enabled


@contextlib.contextmanager
def use_sanitizer(enabled: bool = True):
    """Install (or, with `enabled=False`, suspend) the runtime sanitizer
    for the block. Nests and restores like `use_policy`."""
    global _enabled
    prev = _enabled
    _enabled = bool(enabled)
    try:
        yield
    finally:
        _enabled = prev


def _skip(arr) -> bool:
    """Tracers can't throw host-side; empty arrays have no min/max."""
    return isinstance(arr, jax.core.Tracer) or arr.size == 0


@functools.partial(jax.jit, static_argnames=("p", "what"))
def _gf_checked(arr, *, p: int, what: str):
    def impl(a):
        ok = jnp.all((a >= 0) & (a < p))
        checkify.check(
            ok,
            f"sanitizer[{what}]: GF symbol outside [0, {p}): "
            "min={mn}, max={mx}",
            mn=jnp.min(a), mx=jnp.max(a))
        return jnp.int32(0)
    err, _ = checkify.checkify(impl, errors=checkify.user_checks)(arr)
    return err


@functools.partial(jax.jit, static_argnames=("what",))
def _finite_checked(arr, *, what: str):
    def impl(a):
        checkify.check(
            jnp.all(jnp.isfinite(a)),
            f"sanitizer[{what}]: non-finite value "
            "(nan_count={nans}, inf_count={infs})",
            nans=jnp.sum(jnp.isnan(a)), infs=jnp.sum(jnp.isinf(a)))
        return jnp.int32(0)
    err, _ = checkify.checkify(impl, errors=checkify.user_checks)(arr)
    return err


@functools.partial(jax.jit, static_argnames=("what",))
def _scales_checked(arr, *, what: str):
    def impl(a):
        checkify.check(
            jnp.all(jnp.isfinite(a) & (a >= 0)),
            f"sanitizer[{what}]: quantization scale must be finite and "
            ">= 0 (zero marks an empty/padded page): min={mn}",
            mn=jnp.min(a))
        return jnp.int32(0)
    err, _ = checkify.checkify(impl, errors=checkify.user_checks)(arr)
    return err


def check_gf_symbols(arr, p: int, what: str = "gf") -> None:
    """Raise `SanitizerError` unless every symbol sits in `[0, p)`."""
    if not _enabled:
        return
    arr = jnp.asarray(arr)
    if _skip(arr):
        return
    _gf_checked(arr, p=int(p), what=str(what)).throw()


def check_finite(arr, what: str = "tensor") -> None:
    """Raise `SanitizerError` on any NaN/Inf in a float tensor."""
    if not _enabled:
        return
    arr = jnp.asarray(arr)
    if _skip(arr) or not jnp.issubdtype(arr.dtype, jnp.floating):
        return
    _finite_checked(arr, what=str(what)).throw()


def check_quant_scales(arr, what: str = "scales") -> None:
    """Raise `SanitizerError` on non-finite or negative quantization
    scales (scale 0 is the legal padded/empty-page marker)."""
    if not _enabled:
        return
    arr = jnp.asarray(arr)
    if _skip(arr):
        return
    _scales_checked(arr, what=str(what)).throw()
