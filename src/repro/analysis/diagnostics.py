"""Diagnostic records emitted by the `repro.analysis` rule engine.

A diagnostic pins one rule violation to a file:line:col. The `code` is the
stable `RPL###` identifier used for `# noqa: RPL###` suppression and
`--select` filtering; `message` is the human sentence; `rule_name` is the
short kebab-case rule slug shown by `--list-rules`.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    code: str                 # "RPL003"
    message: str
    path: str                 # posix-style, as passed on the CLI
    line: int                 # 1-indexed
    col: int                  # 0-indexed (ast convention)
    rule_name: str = ""

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"

    def to_json(self) -> dict:
        return {"code": self.code, "message": self.message, "path": self.path,
                "line": self.line, "col": self.col, "rule": self.rule_name}
