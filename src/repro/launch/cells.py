"""The (architecture x input-shape) grid: per-cell launch settings, skip
logic, input ShapeDtypeStructs, and cell-specific sharding rules.

40 assigned cells (10 archs x 4 shapes) + 2 paper_pim cells (the paper's own
technique under serve load, used for §Perf hillclimbing).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, ShapeSpec, get_config
from repro.configs.base import ArchConfig
from repro.distributed.sharding import RULES_SINGLE_POD, RULES_MULTI_POD

# archs too large for replicated-over-data storage: shard params over `data`
# (FSDP) in addition to tensor parallelism over `model`
_BIG = {"mistral_large_123b", "arctic_480b", "llama32_vision_90b"}
# adafactor for the very large models (12B/param AdamW states do not fit)
_ADAFACTOR = {"mistral_large_123b", "arctic_480b", "llama32_vision_90b",
              "deepseek_coder_33b", "jamba_v01_52b"}


@dataclasses.dataclass(frozen=True)
class CellSettings:
    microbatches: int = 8          # grad-accumulation chunks per train step
    optimizer: str = "adamw"
    fsdp_train: bool = True        # shard params over `data` during training
    fsdp_serve: bool = False       # ... and during serving (huge models only)
    remat: bool = True
    notes: str = ""


def settings_for(arch_id: str, shape: ShapeSpec) -> CellSettings:
    opt = "adafactor" if arch_id in _ADAFACTOR else "adamw"
    fsdp_serve = arch_id in _BIG
    mb = 8
    if shape.kind != "train":
        mb = 1
    return CellSettings(microbatches=mb, optimizer=opt,
                        fsdp_train=True, fsdp_serve=fsdp_serve)


def skip_reason(arch_id: str, shape: ShapeSpec) -> str | None:
    cfg = get_config(arch_id)
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: no sub-quadratic mechanism for "
                "524288-token decode (per brief; recorded in DESIGN.md)")
    return None


def list_cells(include_paper: bool = True):
    cells = []
    for a in ARCH_IDS:
        for s in SHAPES.values():
            cells.append((a, s.name))
    if include_paper:
        cells.append(("paper_pim", "prefill_32k"))
        cells.append(("paper_pim", "decode_32k"))
    return cells


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _aux_shape(cfg: ArchConfig, batch: int):
    if not cfg.aux_kind:
        return None
    return jax.ShapeDtypeStruct((batch, cfg.n_aux_tokens, cfg.d_model),
                                jnp.float32)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, object]:
    """Model inputs for the cell's step function (train batch / prompt /
    decode token). Cache/param specs come from eval_shape in steps.py."""
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        d = {"tokens": tok, "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    elif shape.kind == "prefill":
        d = {"tokens": tok}
    else:  # decode: one new token against a seq_len-deep KV cache
        d = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    aux = _aux_shape(cfg, B)
    if aux is not None:
        d["aux"] = aux
    return d


# ---------------------------------------------------------------------------
# cell-specific sharding rules
# ---------------------------------------------------------------------------


def rules_for_cell(mesh, cfg: ArchConfig, shape: ShapeSpec,
                   st: CellSettings) -> dict:
    multi = "pod" in mesh.axis_names
    rules = dict(RULES_MULTI_POD if multi else RULES_SINGLE_POD)
    msize = mesh.shape["model"]

    rules["heads_flat"] = "model"
    # uneven vocabs (granite 49155, whisper 51865) cannot shard as jit args;
    # replicate them (padding the table to a 256-multiple is a §Perf lever)
    rules["vocab"] = "model" if cfg.vocab_size % msize == 0 else None
    # kv projections/heads: shard only when the head count divides the axis
    # (GQA with few KV heads replicates them — standard TP practice)
    kv_div = cfg.n_kv_heads % msize == 0
    rules["kv_flat"] = "model" if kv_div else None
    rules["kv_heads"] = "model" if kv_div else None
    rules["heads"] = "model" if cfg.n_heads % msize == 0 else None
    rules["fsdp"] = "data" if (st.fsdp_train if shape.kind == "train"
                               else st.fsdp_serve) else None

    if shape.kind == "decode":
        if shape.name == "long_500k":
            rules["batch"] = None          # batch=1
            # context parallelism: KV sequence over every idle axis
            rules["kv_seq"] = (("pod", "data") if multi else ("data",))
            if not kv_div:
                rules["kv_seq"] = rules["kv_seq"] + ("model",)
                rules["kv_heads"] = None
        else:
            # 32k-deep caches: batch over DP axes; KV seq over `model` when
            # heads don't divide it (sequence/context parallel attention)
            if not kv_div:
                rules["kv_seq"] = "model"
    return rules
