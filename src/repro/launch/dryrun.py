import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

DOC = """Multi-pod dry-run: prove the distribution config is coherent on the
production meshes without real hardware.

For every (arch x shape) cell and each mesh (single-pod 16x16 = 256 chips,
multi-pod 2x16x16 = 512 chips):
  1. `jax.jit(step, in/out_shardings).lower(*ShapeDtypeStructs).compile()`
     on the FULL config — sharding validation + memory_analysis;
  2. reduced 1-group / 2-group lowerings under identical shardings —
     FLOPs / bytes / collective-wire-bytes composed per costs.py;
  3. JSON artifact per cell under results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch granite_3_2b --shape train_4k [--multi]
  python -m repro.launch.dryrun --all [--jobs N]     # subprocess per cell
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _cell_path(arch: str, shape: str, mesh_name: str) -> str:
    return os.path.abspath(os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json"))


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             skip_costs: bool = False, attn: str = "naive",
             moe: str = "", pim_precoded: bool = False,
             remat_policy: str = "", pim_mode: str = "") -> dict:
    import jax
    from repro.configs import SHAPES, get_config
    from repro.distributed.sharding import use_rules
    from repro.launch import costs as C
    from repro.launch.cells import (input_specs, rules_for_cell, settings_for,
                                    skip_reason)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step

    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    out = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "ok": False}

    reason = skip_reason(arch_id, shape)
    if reason:
        out.update(ok=True, skipped=True, reason=reason)
        return out

    cfg = get_config(arch_id)
    if attn == "flash":
        # flash kernels execute on TPU; cost lowerings use the traffic-free
        # stand-in + analytic kernel accounting (launch/costs.py)
        cfg = dataclasses.replace(cfg, attn_impl="standin")
    if moe:
        cfg = dataclasses.replace(cfg, moe_impl=moe)
    if pim_precoded and cfg.pim.enabled:
        cfg = dataclasses.replace(
            cfg, pim=dataclasses.replace(cfg.pim, precoded=True))
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    if pim_mode and cfg.pim.enabled:
        cfg = dataclasses.replace(
            cfg, pim=dataclasses.replace(cfg.pim, mode=pim_mode))
    out_attn = attn
    st = settings_for(arch_id, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    nd = mesh.devices.size
    rules = rules_for_cell(mesh, cfg, shape, st)
    out["settings"] = dataclasses.asdict(st)
    out["rules"] = {k: list(v) if isinstance(v, tuple) else v
                    for k, v in rules.items()}

    def lower_compile(cfg_v, tag, st_v=None):
        fn, specs, sh_fn = build_step(cfg_v, st_v or st, shape)
        in_sh, out_sh = sh_fn(mesh, rules)
        t0 = time.time()
        with use_rules(mesh, rules):
            jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jf.lower(*specs)
            compiled = lowered.compile()
        dt = time.time() - t0
        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        txt = compiled.as_text()
        colls = C.parse_collectives(txt, nd)
        return {
            "tag": tag,
            "compile_s": round(dt, 2),
            "flops_per_dev": float(ca.get("flops", 0.0)),
            "bytes_per_dev": float(ca.get("bytes accessed", 0.0)),
            "mem": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            "collectives": C.collective_summary(colls),
            "coll_detail": [dataclasses.asdict(c) for c in colls[:200]],
        }

    try:
        full = lower_compile(cfg, "full")
        out["full"] = full
        out["ok"] = True
    except Exception as e:                                  # noqa: BLE001
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
        return out

    if skip_costs:
        return out

    # ---- unrolled-group cost composition (per-device costs) ---------------
    # Static HLO analysis counts `while` bodies once regardless of trip count
    # (verified: scan(8 matmuls) == 1 matmul flops), so the cost variants are
    # lowered with the group loop UNROLLED (n_groups <= 2, no while),
    # microbatching off (flops are mb-invariant), and the Mamba time-chunk
    # widened to the full sequence (associative_scan is plain HLO -> counted).
    #   total(term) = u1(term) + (G - 1) * [u2(term) - u1(term)]
    try:
        import dataclasses as dc
        G = cfg.n_groups
        GE = cfg.encoder_groups
        seq = shape.seq_len if shape.kind != "decode" else cfg.mamba_chunk
        base = dict(unroll_groups=True,
                    mamba_chunk=max(cfg.mamba_chunk, min(seq, 32768)))
        st_cost = dc.replace(st, microbatches=1)
        cfg1 = dc.replace(cfg, n_groups=1, encoder_groups=min(GE, 1), **base)
        cfg2 = dc.replace(cfg, n_groups=2, encoder_groups=min(GE, 1), **base)
        r1 = lower_compile(cfg1, "g1", st_cost)
        r2 = lower_compile(cfg2, "g2", st_cost)
        comp = {}
        for term in ("flops_per_dev", "bytes_per_dev"):
            comp[term] = C.compose_linear(r1[term], r2[term], G)
        comp["collective_wire_bytes"] = C.compose_linear(
            r1["collectives"]["total_wire_bytes"],
            r2["collectives"]["total_wire_bytes"], G)
        if attn == "flash":
            fa_fl, fa_by = C.flash_attention_analytics(cfg, shape)
            comp["flops_per_dev"] += fa_fl / nd
            comp["bytes_per_dev"] += fa_by / nd
            comp["flash_analytic_flops_per_dev"] = fa_fl / nd
            comp["flash_analytic_bytes_per_dev"] = fa_by / nd
        if GE > 1:
            cfgE = dc.replace(cfg, n_groups=1, encoder_groups=2, **base)
            rE = lower_compile(cfgE, "enc2", st_cost)
            for term in ("flops_per_dev", "bytes_per_dev"):
                comp[term] += (GE - 1) * max(rE[term] - r1[term], 0.0)
            comp["collective_wire_bytes"] += (GE - 1) * max(
                rE["collectives"]["total_wire_bytes"]
                - r1["collectives"]["total_wire_bytes"], 0.0)
        out["composed"] = comp
        out["g1"] = {k: r1[k] for k in
                     ("flops_per_dev", "bytes_per_dev", "collectives",
                      "compile_s")}
        out["g2"] = {k: r2[k] for k in
                     ("flops_per_dev", "bytes_per_dev", "collectives",
                      "compile_s")}
    except Exception as e:                                  # noqa: BLE001
        out["cost_error"] = f"{type(e).__name__}: {e}"
        out["cost_traceback"] = traceback.format_exc()[-4000:]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--missing-only", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--skip-costs", action="store_true")
    ap.add_argument("--attn", default="naive", choices=["naive", "flash"])
    ap.add_argument("--moe", default="", choices=["", "sorted_ep", "shard_ep"])
    ap.add_argument("--pim-precoded", action="store_true")
    ap.add_argument("--remat-policy", default="")
    ap.add_argument("--pim-mode", default="")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)

    if args.all:
        from repro.launch.cells import list_cells
        todo = []
        for arch, shape in list_cells():
            for multi in (False, True):
                mesh_name = "multi" if multi else "single"
                path = _cell_path(arch, shape, mesh_name)
                if args.missing_only and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            continue
                todo.append((arch, shape, multi))
        print(f"{len(todo)} cells to run")
        procs = []
        while todo or procs:
            while todo and len(procs) < args.jobs:
                arch, shape, multi = todo.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape]
                if multi:
                    cmd.append("--multi")
                # the roofline table is single-pod; multi-pod proves sharding
                if args.skip_costs or multi:
                    cmd.append("--skip-costs")
                print("start", arch, shape, "multi" if multi else "single",
                      flush=True)
                procs.append((subprocess.Popen(cmd), arch, shape, multi))
            still = []
            for p, arch, shape, multi in procs:
                if p.poll() is None:
                    still.append((p, arch, shape, multi))
                else:
                    print("done", arch, shape,
                          "multi" if multi else "single",
                          "rc=", p.returncode, flush=True)
            procs = still
            time.sleep(2)
        return

    res = run_cell(args.arch, args.shape, args.multi,
                   skip_costs=args.skip_costs, attn=args.attn, moe=args.moe,
                   pim_precoded=args.pim_precoded,
                   remat_policy=args.remat_policy, pim_mode=args.pim_mode)
    res["attn"] = args.attn
    mesh_name = "multi" if args.multi else "single"
    suffix = "" if args.attn == "naive" else f"__{args.attn}"
    if args.moe:
        suffix += f"__{args.moe}"
    if args.pim_precoded:
        suffix += "__precoded"
    if args.remat_policy:
        suffix += f"__{args.remat_policy}"
    if args.pim_mode:
        suffix += f"__{args.pim_mode}"
    path = _cell_path(args.arch, args.shape, mesh_name + suffix)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    status = ("SKIP" if res.get("skipped")
              else "OK" if res["ok"] else "FAIL")
    print(f"[{status}] {args.arch} {args.shape} {mesh_name}")
    if not res["ok"]:
        print(res.get("error"))
        sys.exit(1)


if __name__ == "__main__":
    main()
