"""Batched serving driver with optional NB-LDPC PIM protection.

Prefill the prompt batch, then decode tokens step by step. With
`--protect`, the target projections run through the simulated-PIM +
NB-LDPC path (the paper's deployment scenario); `--fault-rate` injects
the paper's Fig. 6(c) fault model during decode so the ECC actually works.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch paper_pim --reduced \
      --batch 4 --prompt-len 16 --gen 8 --protect --fault-rate 1e-3
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import PIMSpec
from repro.core.context import PIMContext
from repro.models import decode_step, init_caches, init_params, prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_pim")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--protect", action="store_true")
    ap.add_argument("--fault-rate", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_groups=2, d_model=128, n_heads=4, d_ff=256)
    if args.protect and not cfg.pim.enabled:
        cfg = dataclasses.replace(cfg, pim=PIMSpec(
            enabled=True, code_name="wl40_r08", mode="correct", n_iters=4))

    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    aux = (0.02 * jax.random.normal(key, (B, cfg.n_aux_tokens, cfg.d_model))
           if cfg.aux_kind else None)

    ctx = None
    if args.protect:
        base = PIMContext(cfg.pim)
        ctx = (base.with_faults(jax.random.PRNGKey(7), args.fault_rate)
               if args.fault_rate > 0 else base)

    t0 = time.time()
    logits, caches = prefill(params, cfg, prompts, aux=aux, pim_ctx=ctx)
    # re-home caches into max-length buffers for decoding
    full = init_caches(cfg, B, S + args.gen)

    def place(dst, src):
        if dst.shape == src.shape:
            return src
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape,
                                          strict=True)]
        return jnp.pad(src, pad)

    caches = jax.tree.map(place, full, caches)
    print(f"prefill: {tuple(logits.shape)} in {time.time()-t0:.2f}s")

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    outs = [tok]
    jdecode = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos,
                                                       pim_ctx=ctx))
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, caches = jdecode(params, caches, tok, jnp.asarray(S + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print("generated tokens:")
    for b in range(B):
        print(f"  [{b}]", np.asarray(gen[b]).tolist())
    print(f"decode: {args.gen-1} steps x {B} seqs in {dt:.2f}s "
          f"({(args.gen-1)*B/max(dt,1e-9):.1f} tok/s)"
          + ("  [NB-LDPC protected]" if args.protect else ""))
    return np.asarray(gen)


if __name__ == "__main__":
    main()
