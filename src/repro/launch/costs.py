"""Cost extraction from compiled artifacts.

Three sources feed §Roofline:
  1. `compiled.cost_analysis()` — per-device HLO FLOPs / bytes accessed.
  2. `compiled.as_text()` — static HLO, from which we sum collective payloads
     (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute) and convert to *wire* bytes with ring formulas.
  3. Scan-body correction: XLA's cost analysis counts a `while` body ONCE
     (verified empirically), and static text parsing counts each collective
     op once regardless of trip count. The group-scan therefore undercounts
     by ~n_groups. We compose true totals from reduced lowerings under
     identical shardings:
         total = c(1 group) + (G-1) * [c(2 groups) - c(1 group)]
     (+ an analytic term for the Mamba inner time-scan, which the 2-vs-1
     group diff still counts once instead of n_chunks times).
"""
from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"(\w+[\d.]*)\s*=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{\{")


@dataclasses.dataclass
class Collective:
    kind: str
    dtype: str
    elems: int
    group_size: int
    payload_bytes: int     # result-shape bytes (per device)
    wire_bytes: int        # ring-algorithm bytes moved per device


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    return math.prod(int(d) for d in dims.split(",") if d)


def parse_collectives(hlo_text: str, total_devices: int) -> list[Collective]:
    out = []
    for m in _COLL_RE.finditer(hlo_text):
        _name, dtype, dims, kind = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        elems = _shape_elems(dims)
        nbytes = elems * _DTYPE_BYTES[dtype]
        # group size from the op's full line
        line_end = hlo_text.find("\n", m.start())
        line = hlo_text[m.start():line_end]
        g = total_devices
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                g = len([x for x in gl.group(1).split(",") if x.strip()])
        if kind == "all-reduce":
            wire = int(2 * nbytes * (g - 1) / max(g, 1))
        elif kind == "all-gather":
            # result holds the gathered tensor; each device receives (g-1)/g
            wire = int(nbytes * (g - 1) / max(g, 1))
        elif kind == "reduce-scatter":
            # result is the scattered shard; input was g x result
            wire = int(nbytes * (g - 1))
        elif kind == "all-to-all":
            wire = int(nbytes * (g - 1) / max(g, 1))
        else:  # collective-permute: one hop
            wire = nbytes
        out.append(Collective(kind, dtype, elems, g, nbytes, wire))
    return out


def collective_summary(colls: list[Collective]) -> dict[str, float]:
    s: dict[str, float] = {}
    for c in colls:
        s[c.kind] = s.get(c.kind, 0.0) + c.wire_bytes
    s["total_wire_bytes"] = sum(c.wire_bytes for c in colls)
    s["n_ops"] = len(colls)
    return s


# ---------------------------------------------------------------------------
# scan-body composition
# ---------------------------------------------------------------------------


def compose_linear(c1: float, c2: float, n: int) -> float:
    """total for n groups from 1-group and 2-group measurements."""
    body = max(c2 - c1, 0.0)
    return c1 + (n - 1) * body


def mamba_inner_scan_flops(cfg, batch: int, seq: int, n_mamba_layers: int,
                           backward: bool) -> float:
    """Analytic FLOPs of the Mamba chunked time-scan that the 1-vs-2-group
    diff counts once instead of n_chunks times: the *additional* (n_chunks-1)
    chunk bodies per mamba layer.

    Per chunk body (B, C=chunk, di, ds): dA=exp+mul (2), dBu (2),
    associative combine ~3*ceil(log2 C), output einsum (2*ds MACs per (t,d)),
    gate/elementwise ~4 per element of (B,C,di).
    """
    C = cfg.mamba_chunk
    if seq <= C:
        return 0.0
    nch = -(-seq // C)
    B, di, ds = batch, cfg.d_inner, cfg.d_state
    per_body = B * C * di * ds * (2 + 2 + 3 * max(1, math.ceil(math.log2(C)))
                                  + 2) + 4 * B * C * di
    mult = 3.0 if backward else 1.0       # fwd + recompute + bwd under remat
    return (nch - 1) * per_body * n_mamba_layers * mult


def mamba_inner_scan_bytes(cfg, batch: int, seq: int, n_mamba_layers: int,
                           backward: bool) -> float:
    C = cfg.mamba_chunk
    if seq <= C:
        return 0.0
    nch = -(-seq // C)
    B, di, ds = batch, cfg.d_inner, cfg.d_state
    # in-flight (B, C, di, ds) fp32 tensors touched ~6 times per body
    per_body = 6 * B * C * di * ds * 4
    mult = 3.0 if backward else 1.0
    return (nch - 1) * per_body * n_mamba_layers * mult


def count_mamba_layers(cfg) -> int:
    return sum(1 for s in cfg.group_spec if s.kind == "mamba")


# ---------------------------------------------------------------------------
# flash-attention analytic accounting (used with attn_impl="standin")
# ---------------------------------------------------------------------------
# The Pallas flash kernels (kernels/flash_attention.py, validated vs the
# naive oracle) keep all O(Sq*Skv) intermediates VMEM-resident. The dry-run
# cost lowering replaces attention internals with a traffic-free stand-in and
# the true kernel costs are added here from its block-level IO:
#   fwd:  2 matmuls over the unmasked score area -> 4*B*Hq*Sq*Skv*D*frac FLOPs
#         HBM: q read + o write once; k/v re-read once per visited q block;
#         lse (B*Hq*Sq) fp32 write.
#   bwd:  5 matmuls (recompute s, dp, dv, dk, dq) -> 2.5x fwd FLOPs; the dq
#         and dkv kernels each re-stream the operands -> ~3x fwd bytes.
#   remat (training): the fwd kernel runs twice (fwd + recompute-for-bwd).

BLOCK_Q = 512


def _attn_layer_cost(B, Sq, Skv, Hq, Hkv, D, frac, train: bool):
    flops_fwd = 4.0 * B * Hq * Sq * Skv * D * frac
    nq_vis = max(1.0, (Sq / BLOCK_Q) * frac)
    bytes_fwd = (B * Hq * Sq * D * 2 * 2          # q read + o write (bf16)
                 + B * Hkv * Skv * D * 2 * 2 * nq_vis   # k+v re-reads
                 + B * Hq * Sq * 4)               # lse
    if not train:
        return flops_fwd, bytes_fwd
    flops = flops_fwd * (1 + 1 + 2.5)             # fwd + remat-recompute + bwd
    bytes_ = bytes_fwd * (1 + 1 + 3)
    return flops, bytes_


def flash_attention_analytics(cfg, shape) -> tuple:
    """(flops_global, bytes_global) for ALL attention internals of one step
    under the flash kernels. Only 'train' and 'prefill' shapes route
    attention through the kernel (decode keeps the naive (Sq=1) path)."""
    if shape.kind == "decode":
        return 0.0, 0.0
    B, S = shape.global_batch, shape.seq_len
    Hq, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    train = shape.kind == "train"
    fl = by = 0.0
    for spec in cfg.group_spec:
        n = cfg.n_groups
        if spec.kind == "mamba":
            continue
        if spec.kind == "encdec":
            f, b = _attn_layer_cost(B, S, S, Hq, Hkv, D, 0.5, train)   # self
            fl += n * f
            by += n * b
            Na = cfg.n_aux_tokens or 1
            f, b = _attn_layer_cost(B, S, Na, Hq, Hkv, D, 1.0, train)  # cross
            fl += n * f
            by += n * b
            continue
        if spec.cross:
            Na = cfg.n_aux_tokens or 1
            f, b = _attn_layer_cost(B, S, Na, Hq, Hkv, D, 1.0, train)
        else:
            frac = 0.5
            if spec.local_window and spec.local_window < S:
                frac = min(1.0, spec.local_window / S)
            f, b = _attn_layer_cost(B, S, S, Hq, Hkv, D, frac, train)
        fl += n * f
        by += n * b
    if cfg.encoder_groups:
        Na = cfg.n_aux_tokens or 1
        f, b = _attn_layer_cost(B, Na, Na, Hq, Hkv, D, 1.0, train)
        fl += cfg.encoder_groups * f
        by += cfg.encoder_groups * b
    return fl, by
