"""End-to-end training driver.

Production shape: config-driven model, shard-aware resumable data pipeline,
AdamW/Adafactor, atomic checkpoints + RestartManager (crash-resilient),
straggler watchdog, logical-axis sharding on whatever mesh is available.

On this CPU container it trains reduced configs for real (the 100M-scale
end-to-end example); on TPU pods the same driver lowers the full configs —
nothing here is CPU-specific.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
      --reduced --steps 120 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, TokenPipeline
from repro.distributed.fault import RestartManager, StragglerWatchdog
from repro.distributed.sharding import use_rules
from repro.launch.cells import rules_for_cell, settings_for
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train
from repro.models import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-groups", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_groups=args.n_groups, d_model=args.d_model,
                          n_heads=max(4, args.d_model // 64),
                          d_ff=4 * args.d_model, vocab=args.vocab)
    shape = ShapeSpec("custom", args.seq, args.batch, "train")
    st = dataclasses.replace(settings_for(args.arch, shape),
                             microbatches=args.microbatches)

    mesh = make_host_mesh(data=len(jax.devices()))
    rules = rules_for_cell(mesh, cfg, shape, st)

    train_step, _specs, shardings, tx = build_train(
        cfg, st, shape, lr=args.lr, total_steps=args.steps)
    in_sh, out_sh = shardings(mesh, rules)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    mgr = RestartManager(args.ckpt_dir, save_every=args.save_every)
    dog = StragglerWatchdog()

    def init_state():
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        return {"params": params, "opt": tx.init(params)}

    state, start_step, data_state = mgr.restore_or_init(init_state)
    pipe = (TokenPipeline.restore(dcfg, data_state) if data_state
            else TokenPipeline(dcfg, step=start_step))

    jstep = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                    donate_argnums=(0, 1))

    params, opt = state["params"], state["opt"]
    aux = None
    if cfg.aux_kind:
        aux = 0.02 * np.random.default_rng(0).standard_normal(
            (args.batch, cfg.n_aux_tokens, cfg.d_model)).astype(np.float32)

    losses = []
    with use_rules(mesh, rules):
        for step in range(start_step, args.steps):
            dog.step_start()
            batch = next(pipe)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if aux is not None:
                batch["aux"] = jnp.asarray(aux)
            params, opt, metrics = jstep(params, opt, batch)
            dt = dog.step_end(step)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  {dt:.2f}s",
                      flush=True)
            mgr.maybe_save(step, {"params": params, "opt": opt},
                           data_state=pipe.state())

    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"stragglers flagged: {len(dog.flagged)}")
    return losses


if __name__ == "__main__":
    main()
