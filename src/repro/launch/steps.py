"""Step builders: jit-able train / prefill / serve steps + their shardings.

Everything the dry-run and the real drivers need:
  build_train(cfg, st)  -> (step_fn, arg_specs, in_shardings, out_shardings)
  build_prefill(cfg)    -> ...
  build_serve(cfg)      -> ...

Steps close over the config; arguments are pure pytrees so `.lower()` works
with ShapeDtypeStructs. Parameter / optimizer-state / cache shardings are
derived from the logical-axis trees in repro.models via the cell rules.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.context import PIMContext
from repro.distributed.sharding import named_sharding, use_rules
from repro.launch.cells import CellSettings, input_specs
from repro.models import (cache_axes, decode_step, encode_params_for_pim,
                          init_caches, init_params, loss_fn, param_axes,
                          pim_param_axes, prefill)
from repro.optim import make_optimizer, warmup_cosine


def _shard_tree(mesh, rules, axes_tree):
    return jax.tree.map(lambda ax: named_sharding(mesh, rules, ax), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def _replicated(mesh):
    return NamedSharding(mesh, P())


def opt_state_axes(opt_name: str, p_axes, p_shapes):
    """Logical axes for the optimizer state, parallel to optim's state tree."""
    if opt_name == "adamw":
        return {"m": p_axes, "v": p_axes, "step": ()}

    def st(ax, sds):
        if len(sds.shape) >= 2:
            return {"vr": tuple(ax[:-1]), "vc": tuple(ax[:-2]) + (ax[-1],)}
        return {"v": tuple(ax)}

    f = jax.tree.map(st, p_axes, p_shapes,
                     is_leaf=lambda x: isinstance(x, tuple))
    return {"f": f, "step": ()}


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def build_train(cfg: ArchConfig, st: CellSettings, shape: ShapeSpec,
                mesh=None, rules=None, *, lr: float = 3e-4,
                total_steps: int = 10000):
    tx = make_optimizer(st.optimizer, warmup_cosine(lr, 200, total_steps))
    mb = st.microbatches

    def train_step(params, opt_state, batch):
        def mb_loss(p, b):
            return loss_fn(p, cfg, b)

        if mb == 1:
            loss, grads = jax.value_and_grad(mb_loss)(params, batch)
        else:
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            mbatch = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, b):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(mb_loss)(params, b)
                g_acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                     g_acc, g)
                return (g_acc, l_acc + l), None

            (grads, loss), _ = jax.lax.scan(acc, (zeros, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb

        new_params, new_opt, gnorm = tx.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    # ---- specs & shardings -------------------------------------------------
    p_axes = param_axes(cfg)
    p_shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    o_shapes = jax.eval_shape(tx.init, p_shapes)
    o_axes = opt_state_axes(st.optimizer, p_axes, p_shapes)
    batch_specs = input_specs(cfg, shape)

    def shardings(mesh, rules):
        p_sh = _shard_tree(mesh, rules, p_axes)
        o_sh = _shard_tree(mesh, rules, o_axes)
        b_sh = {"tokens": named_sharding(mesh, rules, ("batch", None)),
                "labels": named_sharding(mesh, rules, ("batch", None))}
        if "aux" in batch_specs:
            b_sh["aux"] = named_sharding(mesh, rules, ("batch", None, None))
        m_sh = {"loss": _replicated(mesh), "grad_norm": _replicated(mesh)}
        return (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh)

    arg_specs = (p_shapes, o_shapes, batch_specs)
    return train_step, arg_specs, shardings, tx


# ---------------------------------------------------------------------------
# serving: prefill & decode
# ---------------------------------------------------------------------------


def _maybe_ctx(cfg: ArchConfig) -> PIMContext | None:
    return PIMContext(cfg.pim) if cfg.pim.enabled else None


def build_prefill(cfg: ArchConfig, shape: ShapeSpec):
    ctx = _maybe_ctx(cfg)

    def prefill_step(params, batch):
        logits, caches = prefill(params, cfg, batch["tokens"],
                                 aux=batch.get("aux"), pim_ctx=ctx)
        return logits, caches

    p_axes = param_axes(cfg)
    if cfg.pim.enabled and cfg.pim.precoded:
        p_axes = pim_param_axes(p_axes, cfg)
        p_shapes = jax.eval_shape(lambda: encode_params_for_pim(
            init_params(jax.random.PRNGKey(0), cfg), cfg))
    else:
        p_shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
    batch_specs = input_specs(cfg, shape)
    c_axes = cache_axes(cfg)

    def shardings(mesh, rules):
        p_sh = _shard_tree(mesh, rules, p_axes)
        b_sh = {"tokens": named_sharding(mesh, rules, ("batch", None))}
        if "aux" in batch_specs:
            b_sh["aux"] = named_sharding(mesh, rules, ("batch", None, None))
        lg_sh = named_sharding(mesh, rules, ("batch", None, "vocab"))
        c_sh = _shard_tree(mesh, rules, c_axes)
        return (p_sh, b_sh), (lg_sh, c_sh)

    return prefill_step, (p_shapes, batch_specs), shardings


def build_serve(cfg: ArchConfig, shape: ShapeSpec):
    """One-token decode against a seq_len-deep cache."""
    ctx = _maybe_ctx(cfg)

    def serve_step(params, caches, batch):
        logits, new_caches = decode_step(params, cfg, caches,
                                         batch["tokens"], batch["pos"],
                                         pim_ctx=ctx)
        return logits, new_caches

    p_axes = param_axes(cfg)
    if cfg.pim.enabled and cfg.pim.precoded:
        p_axes = pim_param_axes(p_axes, cfg)
        p_shapes = jax.eval_shape(lambda: encode_params_for_pim(
            init_params(jax.random.PRNGKey(0), cfg), cfg))
    else:
        p_shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
    c_shapes = jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, shape.seq_len))
    c_axes = cache_axes(cfg)
    batch_specs = input_specs(cfg, shape)

    def shardings(mesh, rules):
        p_sh = _shard_tree(mesh, rules, p_axes)
        c_sh = _shard_tree(mesh, rules, c_axes)
        b_sh = {"tokens": named_sharding(mesh, rules, ("batch", None)),
                "pos": _replicated(mesh)}
        if "aux" in batch_specs:
            b_sh["aux"] = named_sharding(mesh, rules, ("batch", None, None))
        lg_sh = named_sharding(mesh, rules, ("batch", None, "vocab"))
        return (p_sh, c_sh, b_sh), (lg_sh, c_sh)

    return serve_step, (p_shapes, c_shapes, batch_specs), shardings


def build_step(cfg: ArchConfig, st: CellSettings, shape: ShapeSpec):
    """Dispatch on the shape kind. Returns (fn, arg_specs, shardings_fn)."""
    if shape.kind == "train":
        fn, specs, sh, _tx = build_train(cfg, st, shape)
        return fn, specs, sh
    if shape.kind == "prefill":
        return build_prefill(cfg, shape)
    return build_serve(cfg, shape)
