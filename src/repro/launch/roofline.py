"""Roofline analysis over the dry-run artifacts (§Roofline).

Per (arch x shape) on the single-pod mesh:
    compute term    = HLO_FLOPs_per_dev / peak_FLOP/s
    memory term     = HLO_bytes_per_dev / HBM_bw
    collective term = collective_wire_bytes_per_dev / ICI_link_bw
(Terms are seconds-per-step; the largest term is the bottleneck. HLO counts
come from the unrolled cost lowerings — see dryrun.py.)

Also reports MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N_active for
MoE, and the MODEL/HLO ratio (useful-compute fraction; remat and the
replicated-head inefficiency show up here).

Usage:  python -m repro.launch.roofline [--mesh single] [--csv out.csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

# TPU v5e constants (per chip), from the brief
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def count_params(cfg):
    """(total params, active params) from shapes (no allocation)."""
    import jax
    from repro.models import init_params

    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))

    def sizes(tree):
        return sum(np.prod(l.shape) for l in jax.tree.leaves(tree))

    total = sizes(shapes)
    active = total
    if cfg.n_experts:
        moe = 0
        for pos in shapes["groups"].values():
            if "moe" in pos:
                e = {k: v for k, v in pos["moe"].items() if k != "router"}
                moe += sizes(e)
        active = total - moe + moe * cfg.top_k / cfg.n_experts
    return float(total), float(active)


def model_flops(cfg, shape, n_active: float) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def analyze_cell(path: str):
    with open(path) as f:
        d = json.load(f)
    if d.get("skipped") or not d.get("ok") or "composed" not in d:
        return d, None
    from repro.configs import SHAPES, get_config
    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    nd = 512 if d["mesh"] == "multi" else 256

    comp = d["composed"]
    t_compute = comp["flops_per_dev"] / PEAK_FLOPS
    t_memory = comp["bytes_per_dev"] / HBM_BW
    t_coll = comp["collective_wire_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    total, active = count_params(cfg)
    mf = model_flops(cfg, shape, active)
    hlo_global = comp["flops_per_dev"] * nd
    ratio = mf / hlo_global if hlo_global else 0.0

    # roofline fraction: useful model flops per second at the bottleneck
    step_time = max(terms.values())
    mfu = mf / nd / step_time / PEAK_FLOPS if step_time else 0.0

    mem = d["full"]["mem"]
    hbm_gb = (mem["argument_bytes"] + mem["temp_bytes"]
              + mem["output_bytes"] - mem["alias_bytes"]) / 2**30

    row = {
        "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
        "params_B": total / 1e9, "active_B": active / 1e9,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": bottleneck,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": ratio, "roofline_mfu": mfu,
        "hbm_gb_per_dev": hbm_gb,
    }
    return d, row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--variants", action="store_true",
                    help="include optimized-variant artifacts (__flash etc.)")
    args = ap.parse_args()

    rows, skips, fails = [], [], []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        name = os.path.basename(path)[:-5]
        is_variant = name.count("__") > 2
        if is_variant != args.variants:
            continue
        with open(path) as f:
            d = json.load(f)
        if d["mesh"] != args.mesh:
            continue
        if d.get("skipped"):
            skips.append((d["arch"], d["shape"], d["reason"]))
            continue
        if not d.get("ok"):
            fails.append((d["arch"], d["shape"], d.get("error", "?")[:100]))
            continue
        _, row = analyze_cell(path)
        if row:
            if is_variant:
                tail = name.split("__", 3)[-1]
                row["arch"] = f"{d['arch']}+{tail}"
            rows.append(row)

    hdr = (f"{'arch':24s} {'shape':12s} {'prm(B)':>7s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} {'bound':>10s} {'useful':>7s} "
           f"{'MFU':>6s} {'HBM(GiB)':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(f"{r['arch'][:24]:24s} {r['shape']:12s} {r['params_B']:7.1f} "
              f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
              f"{r['t_collective_s']:9.4f} {r['bottleneck']:>10s} "
              f"{r['useful_ratio']:7.3f} {r['roofline_mfu']:6.3f} "
              f"{r['hbm_gb_per_dev']:8.2f}")
    for a, s, reason in skips:
        print(f"{a:24s} {s:12s} SKIP: {reason[:80]}")
    for a, s, e in fails:
        print(f"{a:24s} {s:12s} FAIL: {e}")

    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
