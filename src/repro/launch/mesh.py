"""Production meshes (TPU v5e target).

Functions, never module-level constants — importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)                    # 256 chips
MULTI_POD = (2, 16, 16)                  # 2 pods x 256 = 512 chips


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: `axis_types` (and
    `jax.sharding.AxisType`) only exist on newer jax; older releases have
    exactly the Auto behavior, so dropping the argument is equivalent."""
    try:
        kinds = (jax.sharding.AxisType.Auto,) * len(shape)
    except AttributeError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=kinds)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return compat_make_mesh((data, model), ("data", "model"))
