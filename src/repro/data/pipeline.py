"""Deterministic, shard-aware, resumable synthetic token pipeline.

Production framing: every data-parallel host pulls *its* slice of the global
batch, derived purely from (seed, step, shard_index) — so (a) any host can be
restarted at any step with zero coordination, (b) elastic re-sharding (resume
on a different data-parallel degree) re-partitions the same global stream,
and (c) the pipeline state is one integer (the step), which the checkpoint
manifest records.

The synthetic stream is a Zipf-weighted order-2 Markov chain over the vocab —
enough structure that the end-to-end training example shows a real loss curve
(a pure-uniform stream would bottom out at log V immediately).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # Zipf exponent for unigram skew
    markov_states: int = 64      # order-2 chain folded into this many states


class TokenPipeline:
    """Iterator of {tokens, labels} with exact-resume semantics."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 num_shards: int = 1, step: int = 0):
        assert cfg.global_batch % num_shards == 0, \
            f"global_batch {cfg.global_batch} % shards {num_shards} != 0"
        self.cfg = cfg
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.step = step
        self._build_chain()

    def _build_chain(self):
        c = self.cfg
        rng = np.random.default_rng(c.seed)
        V, S = c.vocab_size, c.markov_states
        # Zipf unigram over vocab; per-state sparse next-token preferences
        ranks = np.arange(1, V + 1, dtype=np.float64)
        uni = ranks ** (-c.zipf_a)
        self._uni = uni / uni.sum()
        self._state_shift = rng.integers(0, V, size=S)   # state-dep. rotation
        self._mix = 0.5                                   # chain vs unigram

    def _sample_batch(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        bs = c.global_batch // self.num_shards
        # key derived from (seed, step, shard): restart-stable, shard-disjoint
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, self.shard_index]))
        V = c.vocab_size
        toks = np.empty((bs, c.seq_len + 1), np.int64)
        toks[:, 0] = rng.choice(V, size=bs, p=self._uni)
        u = rng.random((bs, c.seq_len))
        fresh = rng.choice(V, size=(bs, c.seq_len), p=self._uni)
        for t in range(1, c.seq_len + 1):
            state = toks[:, t - 1] % self._state_shift.size
            chained = (toks[:, t - 1] + self._state_shift[state]) % V
            toks[:, t] = np.where(u[:, t - 1] < self._mix,
                                  chained, fresh[:, t - 1])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        batch = self._sample_batch(self.step)
        self.step += 1
        return batch

    # -- resume protocol ----------------------------------------------------

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict, shard_index: int = 0,
                num_shards: int = 1) -> "TokenPipeline":
        assert state["seed"] == cfg.seed, "resuming with a different data seed"
        return cls(cfg, shard_index, num_shards, step=state["step"])
