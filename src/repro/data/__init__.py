"""Deterministic shard-aware resumable data pipeline."""
from .pipeline import DataConfig, TokenPipeline
