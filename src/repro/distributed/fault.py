"""Fault tolerance: restart manager and straggler watchdog.

At thousand-node scale the framework assumes failures are routine, not
exceptional (DESIGN.md §5):

- `RestartManager` drives the train loop: it restores the newest checkpoint
  (params + optimizer + data-pipeline step) on entry, saves every
  `save_every` steps, and `run()` retries the loop across worker crashes
  with bounded restarts — the single-process analogue of a cluster
  controller re-scheduling a failed pod onto a fresh host.
- `StragglerWatchdog` tracks a step-time EWMA; a step slower than
  `threshold ×` the EWMA is flagged. On a real multi-host deployment the
  flag feeds the backup-replica policy (re-dispatch the slow host's shard);
  here it logs and counts, and the policy hook is injectable.
- `elastic_shardings()` re-derives NamedShardings for a *different* mesh
  than the one a checkpoint was written on — restores are device-count
  independent because checkpoints store full (unsharded) arrays.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections.abc import Callable
from typing import Any

import jax

from repro import checkpoint as ckpt
from repro.distributed.sharding import named_sharding

log = logging.getLogger("repro.fault")


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 on_straggler: Callable[[int, float, float], None] | None
                 = None):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: float | None = None
        self.flagged: list[tuple[int, float]] = []
        self.on_straggler = on_straggler
        self._t0: float | None = None

    def step_start(self):
        self._t0 = time.perf_counter()

    def step_end(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        if self.ewma is not None and dt > self.threshold * self.ewma:
            self.flagged.append((step, dt))
            log.warning("straggler: step %d took %.3fs (EWMA %.3fs)",
                        step, dt, self.ewma)
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt)
        return dt


def elastic_shardings(mesh, rules: dict, axes_tree):
    """Pytree of NamedShardings for `axes_tree` (logical axes) on `mesh`."""
    return jax.tree.map(
        lambda ax: named_sharding(mesh, rules, ax), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


@dataclasses.dataclass
class RestartManager:
    directory: str
    save_every: int = 50
    max_restarts: int = 3
    protect: bool = False

    def restore_or_init(self, init_fn: Callable[[], Any], template=None,
                        shardings=None):
        """Returns (state, start_step, data_state). `state` comes from the
        newest checkpoint if one exists, else `init_fn()`."""
        step = ckpt.latest_step(self.directory)
        if step is None:
            state = init_fn()
            return state, 0, None
        template = template if template is not None else init_fn()
        state, manifest = ckpt.restore_checkpoint(
            self.directory, template, step=step, shardings=shardings)
        log.info("restored checkpoint step=%d from %s", step, self.directory)
        return state, step, manifest["extra"].get("data_state")

    def maybe_save(self, step: int, state, data_state: dict | None = None):
        if step > 0 and step % self.save_every == 0:
            ckpt.save_checkpoint(self.directory, step, state,
                                 extra={"data_state": data_state},
                                 protect=self.protect)

    def run(self, make_loop: Callable[[int, dict | None], int],
            init_fn: Callable[[], Any]):
        """Crash-resilient driver: `make_loop(start_step, data_state)` runs
        until done (returns final step) or raises; on exception we restore
        the newest checkpoint and re-enter, up to max_restarts."""
        restarts = 0
        while True:
            state, start, data_state = self.restore_or_init(init_fn)
            try:
                return make_loop(start, data_state)
            except Exception as e:                     # noqa: BLE001
                restarts += 1
                log.error("worker failed at restart %d: %s", restarts, e)
                if restarts > self.max_restarts:
                    raise
