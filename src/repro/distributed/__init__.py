"""Distribution: logical-axis sharding, fault tolerance, compression."""
from .sharding import (use_rules, rules_for, constrain, named_sharding,
                       resolve_spec, active_mesh, RULES_SINGLE_POD,
                       RULES_MULTI_POD)
from .fault import RestartManager, StragglerWatchdog, elastic_shardings
