"""Logical-axis sharding: models annotate tensors with *logical* axis names;
a per-launch rule table maps them to physical mesh axes.

Models call `constrain(x, "batch", None, "d_ff")` — a no-op when no mesh/rules
are active (CPU unit tests), a `with_sharding_constraint` under an active
`use_rules(mesh, rules)` context (dry-run / production launch).

Also home of `decode_sharded`, the data-parallel batched-decode entry point:
NB-LDPC decode is per-codeword independent, so a `shard_map` over the batch
axis runs each device's slice through the full iterative decoder with zero
collectives.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from collections.abc import Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:        # legacy home of shard_map (jax <= 0.4.x); removed in newer jax
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
except ImportError:
    _legacy_shard_map = None

_CTX = threading.local()

# Default logical->physical tables. `None` entries mean "replicated".
# A rule value may be a string (one mesh axis) or a tuple of mesh axes.
RULES_SINGLE_POD = {
    "batch": "data",
    "expert": "model",
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "d_inner": "model",
    "vocab": "model",
    "kv_seq": None,       # becomes "data" for long-context decode cells
    "seq": None,
    "d_model": None,
    "code_blocks": "model",
}
RULES_MULTI_POD = dict(RULES_SINGLE_POD, batch=("pod", "data"))


def rules_for(mesh: Mesh, *, seq_sharded_kv: bool = False) -> dict:
    rules = dict(RULES_MULTI_POD if "pod" in mesh.axis_names else RULES_SINGLE_POD)
    if seq_sharded_kv:
        # long_500k: batch=1 -> shard the KV-cache sequence dim over `data`
        rules["kv_seq"] = "data"
        rules["batch"] = "pod" if "pod" in mesh.axis_names else None
    return rules


@contextmanager
def use_rules(mesh: Mesh | None, rules: dict | None = None):
    prev = getattr(_CTX, "state", None)
    if mesh is None:
        _CTX.state = None
    else:
        _CTX.state = (mesh, rules if rules is not None else rules_for(mesh))
    try:
        yield
    finally:
        _CTX.state = prev


def active_mesh() -> Mesh | None:
    st = getattr(_CTX, "state", None)
    return st[0] if st else None


def resolve_spec(axes) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    st = getattr(_CTX, "state", None)
    if st is None:
        return P()
    _, rules = st
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        else:
            out.append(rules.get(a))
    return P(*out)


def constrain(x, *axes):
    """with_sharding_constraint by logical axes; no-op without active rules."""
    st = getattr(_CTX, "state", None)
    if st is None:
        return x
    mesh, _ = st
    spec = resolve_spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, rules: dict, axes) -> NamedSharding:
    out = []
    for a in axes:
        out.append(None if a is None else rules.get(a))
    return NamedSharding(mesh, P(*out))


# ---------------------------------------------------------------------------
# sharded batch decode
# ---------------------------------------------------------------------------

def compat_shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """shard_map across jax versions: newer jax exposes `jax.shard_map` with
    `check_vma`; older releases have `jax.experimental.shard_map.shard_map`
    with `check_rep`. `check=False` everywhere — the decode/MoE bodies use
    while_loop/collectives patterns the static replication checker rejects."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check)


def data_mesh(axis_name: str = "data") -> Mesh:
    """1-D mesh over every visible device, for batch-parallel decode."""
    return Mesh(np.array(jax.devices()), (axis_name,))


def decode_sharded(code, y, *, mesh: Mesh | None = None,
                   axis_name: str = "data", n_iters: int = 10,
                   llv_scale: float = 4.0, llv_mode: str = "manhattan",
                   early_exit: bool = False, damping: float = 0.0,
                   cn_fbp: Callable | None = None):
    """Shard batched integer decode across devices along the batch axis.

    y: (B, n) received integer words. B is padded to a multiple of the mesh
    size with all-zero words (valid codewords — they converge immediately)
    and the pad is stripped from every output. Decode is per-codeword
    independent, so the shard_map introduces no collectives; each device
    runs the full iterative decoder on its local slice.

    Returns (y_corrected (B, n), DecodeResult) exactly like
    `repro.core.decode.decode_integers`. Wrap calls in `jax.jit` (or use
    `repro.core.protected.decode_stream`) to amortize trace cost on hot
    paths.
    """
    from repro.core.decode import DecodeResult, decode_integers

    if mesh is None:
        mesh = data_mesh(axis_name)
    ndev = mesh.shape[axis_name]
    B = y.shape[0]
    pad = (-B) % ndev
    if pad:
        y = jax.numpy.concatenate(
            [y, jax.numpy.zeros((pad, y.shape[1]), y.dtype)], axis=0)

    def local_decode(y_local):
        return decode_integers(code, y_local, n_iters=n_iters,
                               llv_scale=llv_scale, llv_mode=llv_mode,
                               early_exit=early_exit, damping=damping,
                               cn_fbp=cn_fbp)

    spec = P(axis_name)
    # check=False: jax<=0.4.x has no replication rule for while_loop
    # (the early-exit path); outputs are all batch-sharded anyway.
    y_corr, res = compat_shard_map(
        local_decode, mesh=mesh, in_specs=spec,
        out_specs=(spec, DecodeResult(spec, spec, spec, spec)))(y)
    if pad:
        y_corr = y_corr[:B]
        res = DecodeResult(res.symbols[:B], res.llv_totals[:B],
                           res.detect_fail[:B], res.iterations[:B])
    return y_corr, res


def shard_page(page, mesh: Mesh, axis_name: str = "data"):
    """Place a (page_words, n) protected-store page row-sharded across the
    mesh devices (the word axis is the paged analogue of the batch axis —
    per-word independence means scan/decode over a sharded page introduces
    no collectives). Used by `repro.memory.paged.PagedProtectedStore` so
    device-resident pages live distributed, not replicated."""
    return jax.device_put(page, NamedSharding(mesh, P(axis_name)))


def scan_syndromes_sharded(code, y, *, mesh: Mesh | None = None,
                           axis_name: str = "data",
                           interpret: bool | None = None):
    """Fan the fused scrub syndrome scan across devices along the batch axis.

    y: (B, n) stored level-words -> (B,) bool flagged mask. Like
    `decode_sharded`, B is padded to a mesh-size multiple with all-zero words
    (valid codewords — never flagged) and the pad is stripped; the scan is
    per-word independent, so the shard_map introduces no collectives. Each
    device runs `repro.kernels.ops.scan_syndromes` (the fused Pallas kernel)
    on its local page slice — this is the `MemoryController` device backend's
    multi-device path for paged scrub sweeps.
    """
    from repro.kernels.ops import scan_syndromes

    # the fused kernel accumulates int32: every per-word syndrome sum is
    # bounded by n*(p-1)^2, which must stay below 2^31 on every shard (the
    # MemoryController routes larger codes to its exact int64 host path)
    assert code.n * (code.p - 1) ** 2 < 2 ** 31, (
        f"scan_syndromes_sharded int32 bound exceeded: "
        f"{code.n} * ({code.p}-1)^2 >= 2^31 — use the exact host scan")

    if mesh is None:
        mesh = data_mesh(axis_name)
    ndev = mesh.shape[axis_name]
    B = y.shape[0]
    pad = (-B) % ndev
    if pad:
        y = jax.numpy.concatenate(
            [y, jax.numpy.zeros((pad, y.shape[1]), y.dtype)], axis=0)
    ht = jax.numpy.asarray(code.H.T, jax.numpy.int32)

    def local_scan(y_local):
        return scan_syndromes(y_local, ht, code.p, interpret=interpret)

    spec = P(axis_name)
    flags = compat_shard_map(local_scan, mesh=mesh, in_specs=spec,
                             out_specs=spec)(y)
    return flags[:B] if pad else flags
