"""Logical-axis sharding: models annotate tensors with *logical* axis names;
a per-launch rule table maps them to physical mesh axes.

Models call `constrain(x, "batch", None, "d_ff")` — a no-op when no mesh/rules
are active (CPU unit tests), a `with_sharding_constraint` under an active
`use_rules(mesh, rules)` context (dry-run / production launch).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()

# Default logical->physical tables. `None` entries mean "replicated".
# A rule value may be a string (one mesh axis) or a tuple of mesh axes.
RULES_SINGLE_POD = {
    "batch": "data",
    "expert": "model",
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "d_inner": "model",
    "vocab": "model",
    "kv_seq": None,       # becomes "data" for long-context decode cells
    "seq": None,
    "d_model": None,
    "code_blocks": "model",
}
RULES_MULTI_POD = dict(RULES_SINGLE_POD, batch=("pod", "data"))


def rules_for(mesh: Mesh, *, seq_sharded_kv: bool = False) -> dict:
    rules = dict(RULES_MULTI_POD if "pod" in mesh.axis_names else RULES_SINGLE_POD)
    if seq_sharded_kv:
        # long_500k: batch=1 -> shard the KV-cache sequence dim over `data`
        rules["kv_seq"] = "data"
        rules["batch"] = "pod" if "pod" in mesh.axis_names else None
    return rules


@contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = getattr(_CTX, "state", None)
    if mesh is None:
        _CTX.state = None
    else:
        _CTX.state = (mesh, rules if rules is not None else rules_for(mesh))
    try:
        yield
    finally:
        _CTX.state = prev


def active_mesh() -> Optional[Mesh]:
    st = getattr(_CTX, "state", None)
    return st[0] if st else None


def resolve_spec(axes) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    st = getattr(_CTX, "state", None)
    if st is None:
        return P()
    _, rules = st
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        else:
            out.append(rules.get(a))
    return P(*out)


def constrain(x, *axes):
    """with_sharding_constraint by logical axes; no-op without active rules."""
    st = getattr(_CTX, "state", None)
    if st is None:
        return x
    mesh, _ = st
    spec = resolve_spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, rules: dict, axes) -> NamedSharding:
    out = []
    for a in axes:
        out.append(None if a is None else rules.get(a))
    return NamedSharding(mesh, P(*out))
