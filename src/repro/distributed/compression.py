"""Error-feedback int8 gradient compression for cross-pod data parallelism.

At multi-pod scale the gradient all-reduce crosses the (slow) inter-pod DCN;
compressing that leg 4x (fp32 -> int8 + per-tensor scale) is a standard
distributed-optimization trick. Error feedback keeps the quantization
*unbiased over time*: the residual of each round is added back before the
next quantization, so SGD converges to the uncompressed fixed point.

Two layers:
  - `quantize_ef` / `dequantize`: the wire format + error-feedback state.
  - `compressed_psum(x, axis_name)`: drop-in psum replacement usable inside
    `shard_map` over the `pod` mesh axis — quantize locally, all-reduce the
    int32-widened payload, dequantize once. The intra-pod reduction stays
    full-precision (fast ICI); only the pod-axis leg is compressed.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: jnp.ndarray        # same shape as the tensor, fp32


def init_ef(tree):
    return jax.tree.map(lambda x: EFState(jnp.zeros_like(x, jnp.float32)), tree)


def quantize_ef(x: jnp.ndarray, ef: EFState):
    """fp32 -> (int8 payload, scale, new EFState). Error feedback: the value
    we fail to represent this round is carried to the next."""
    xf = x.astype(jnp.float32) + ef.residual
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    resid = xf - q.astype(jnp.float32) * scale
    return q, scale, EFState(resid)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, ef: EFState, axis_name: str):
    """All-reduce `x` over `axis_name` with an int8 wire format + error
    feedback. Call inside shard_map; returns (mean-reduced x, new EFState).

    The int8 payload is widened to int32 for the additive collective (p
    participants sum to <= p*127, exact in int32); scales are all-gathered
    implicitly by reducing q*scale contributions — we instead psum the
    *dequantized* int grid per participant to keep the collective a single
    psum: wire bytes ~ int8 + one scalar, modeled on the int8 payload.
    """
    q, scale, ef = quantize_ef(x, ef)
    # each participant contributes its own grid; sum of (q_i * s_i) is exact
    # as int32 payload + f32 scale per participant (scales reduced alongside)
    part = q.astype(jnp.int32)
    summed = jax.lax.psum(part * 1, axis_name)            # int32 collective
    # scales differ per pod: psum the scaled residual correction term
    corr = jax.lax.psum(q.astype(jnp.float32) * (scale - jax.lax.pmean(
        scale, axis_name)), axis_name)
    mean_scale = jax.lax.pmean(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    out = (summed.astype(jnp.float32) * mean_scale + corr) / n
    return out, ef
