"""granite-3-2b [dense, GQA] — hf:ibm-granite/granite-3.0-2b-base."""
from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=49155,
    group_spec=(LayerSpec(kind="attn"),), n_groups=40,
    rope_theta=10000.0, act="silu", tie_embeddings=True,
)
