"""llama-3.2-vision-90b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision family.

100 layers: a cross-attention (image) layer after every 4 self-attention
layers (20 cross + 80 self). The vision tower is a STUB per the brief:
input_specs() provides precomputed patch embeddings (B, 1601, d_model).
"""
from .base import ArchConfig, LayerSpec

_spec = (LayerSpec(kind="attn"),) * 4 + (LayerSpec(kind="attn", cross=True),)

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    group_spec=_spec, n_groups=20,
    aux_kind="image", n_aux_tokens=1601,
    rope_theta=500000.0, act="silu",
)
