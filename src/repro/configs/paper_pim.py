"""paper_pim — the paper's own deployment scenario as an architecture config.

A ~2B dense LM served on (simulated) PIM hardware with NB-LDPC protection
enabled on the attn-output and MLP-down projections — the configuration whose
roofline/hillclimb represents the paper's technique itself (serve mode;
protection is a deploy-time feature per DESIGN.md §4).
"""
from .base import ArchConfig, LayerSpec, PIMSpec

CONFIG = ArchConfig(
    name="paper-pim-2b", family="dense",
    d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=49155,
    group_spec=(LayerSpec(kind="attn"),), n_groups=24,
    rope_theta=10000.0, act="silu", tie_embeddings=True,
    pim=PIMSpec(enabled=True, code_name="wl320_r08", mode="correct",
                n_iters=4, damping=0.3,
                targets=("mlp_down", "attn_o"),
                row_parallelism=64, adc_levels=0, use_kernels=False),
)
