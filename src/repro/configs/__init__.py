"""Architecture configs for the assigned (arch x shape) grid + the paper's own."""
from .base import (ArchConfig, LayerSpec, PIMSpec, ShapeSpec, SHAPES,
                   ARCH_IDS, get_config)
