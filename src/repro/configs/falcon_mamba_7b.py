"""falcon-mamba-7b [ssm, attn-free] — arXiv:2410.05355.

64 pure Mamba-1 blocks (no attention, no separate FFN: d_ff=0).
d_inner = expand * d_model = 8192, ssm_state = 16. n_heads is unused
(attention-free) but kept for config completeness.
"""
from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab_size=65024,
    group_spec=(LayerSpec(kind="mamba"),), n_groups=64,
    d_state=16, d_conv=4, expand=2, mamba_chunk=64,
    act="silu", sub_quadratic=True,
)
