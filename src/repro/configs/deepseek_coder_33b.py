"""deepseek-coder-33b [dense, llama-arch] — arXiv:2401.14196."""
from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19200, vocab_size=32256,
    group_spec=(LayerSpec(kind="attn"),), n_groups=62,
    rope_theta=100000.0, act="silu",
)
