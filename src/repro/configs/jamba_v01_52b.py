"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887.

Jamba block = 8 layers: attention at in-block index 4, Mamba elsewhere
(1:7 attn:mamba); MoE (16 experts, top-2) on every odd layer, dense MLP
(d_ff=14336) on even layers. 4 blocks = 32 layers.
"""
from .base import ArchConfig, LayerSpec

_spec = tuple(
    LayerSpec(kind=("attn" if i == 4 else "mamba"), moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    group_spec=_spec, n_groups=4,
    n_experts=16, top_k=2, expert_d_ff=14336, capacity_factor=1.25,
    d_state=16, d_conv=4, expand=2, mamba_chunk=64,
    rope_theta=10000.0, act="silu",
    sub_quadratic=True,
)
