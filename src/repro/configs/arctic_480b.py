"""arctic-480b [moe] — hf:Snowflake/snowflake-arctic-base.

Dense-MoE hybrid: every layer has a 128-expert top-2 MoE FFN *in parallel
with* a dense residual MLP (d_ff=4864 both).
"""
from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    group_spec=(LayerSpec(kind="attn", moe=True, dense_residual=True),),
    n_groups=35,
    n_experts=128, top_k=2, expert_d_ff=4864, capacity_factor=1.25,
    rope_theta=10000.0, act="silu",
)
