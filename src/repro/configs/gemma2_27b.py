"""gemma2-27b [dense; local+global alternating, logit softcaps] — arXiv:2408.00118.

head_dim=128 per the HF config (d_model/n_heads=144 is not the released
geometry). Local layers use a 4096-token sliding window; logits are
soft-capped (attn 50.0, final 30.0); embeddings scaled by sqrt(d_model).
"""
from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gemma2-27b", family="dense",
    d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    group_spec=(LayerSpec(kind="attn", local_window=4096),
                LayerSpec(kind="attn")),
    n_groups=23,
    rope_theta=10000.0, act="gelu",
    softcap_attn=50.0, softcap_final=30.0,
    embed_scale=True, tie_embeddings=True,
    sub_quadratic=True,   # local layers O(S·W); long_500k runs w/ seq-sharded KV
)
