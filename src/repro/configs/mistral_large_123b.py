"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407."""
from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mistral-large-123b", family="dense",
    d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=32768,
    group_spec=(LayerSpec(kind="attn"),), n_groups=88,
    rope_theta=1000000.0, act="silu",
)
