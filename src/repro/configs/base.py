"""Architecture configuration schema + registry.

Each assigned architecture gets a module `repro/configs/<id>.py` exporting
`CONFIG: ArchConfig`. Models are built from the config alone (repro.models.lm).

A transformer stack is described as `n_groups` repetitions of `group_spec`
(a tuple of LayerSpec) — uniform stacks have a single-entry spec; gemma2
alternates (local, global); jamba repeats an 8-layer mamba/attn/MoE block;
llama-3.2-vision inserts a cross-attention layer every 5th layer.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "deepseek_coder_33b", "granite_3_2b", "gemma2_27b", "mistral_large_123b",
    "arctic_480b", "olmoe_1b_7b", "whisper_small", "jamba_v01_52b",
    "llama32_vision_90b", "falcon_mamba_7b",
]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"            # "attn" | "mamba"
    local_window: int = 0         # sliding-window size; 0 = global attention
    cross: bool = False           # cross-attention (kv from aux embeddings)
    moe: bool = False             # MoE FFN instead of dense MLP
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE


@dataclasses.dataclass(frozen=True)
class PIMSpec:
    """The paper's technique, as deploy-time layer protection."""
    enabled: bool = False
    code_name: str = "wl320_r08"
    mode: str = "correct"              # off | detect | correct
    n_iters: int = 4
    damping: float = 0.3
    targets: tuple[str, ...] = ("mlp_down", "attn_o")
    row_parallelism: int = 64
    adc_levels: int = 0
    use_kernels: bool = False          # dispatch FBP to the Pallas kernel
    precoded: bool = False             # deploy-time: store ternary+NB-LDPC
                                       # encoded int8 weights as params
                                       # (no per-step ternarize/encode)
    correct_budget: int = 16           # mode="correct_budget": max words
                                       # FBP-decoded per protected matmul


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    vocab_size: int
    d_ff: int
    group_spec: tuple[LayerSpec, ...]
    n_groups: int
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "sorted_ep"   # sorted_ep | dense (oracle)
    # --- attention ---
    rope_theta: float = 10000.0
    softcap_attn: float = 0.0
    softcap_final: float = 0.0
    act: str = "silu"
    # --- mamba ---
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    mamba_chunk: int = 16         # inner time-scan chunking (training)
    # --- enc-dec / aux-modal inputs ---
    encoder_groups: int = 0       # whisper: #encoder layers (own scan)
    aux_kind: str = ""            # "" | "audio" | "image"
    n_aux_tokens: int = 0         # image tokens; audio uses seq_len frames
    # --- misc ---
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma: scale embeddings by sqrt(d_model)
    norm_eps: float = 1e-5
    sub_quadratic: bool = False   # eligible for long_500k decode
    unroll_groups: bool = False   # Python-loop over groups (cost lowerings:
                                  # static HLO analysis counts while bodies
                                  # once, so true costs need unrolled graphs)
    attn_impl: str = "naive"      # naive | flash (Pallas kernel) | standin
                                  # (cost lowerings: attention internals are
                                  # accounted analytically per the kernel's
                                  # true HBM traffic; see launch/costs.py)
    pim: PIMSpec = PIMSpec()
    remat: bool = True
    remat_policy: str = "full"    # full (save nothing) | dots (save matmul
                                  # outputs: no recompute, more live bytes)

    @property
    def n_layers(self) -> int:
        return self.n_groups * len(self.group_spec) + self.encoder_groups

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, (self.d_model + 15) // 16)

    def reduced(self, *, n_groups: int = 1, encoder_groups: int | None = None,
                d_model: int = 64, n_heads: int = 4, n_kv_heads: int | None = None,
                d_ff: int = 128, vocab: int = 512, n_experts: int | None = None,
                **kw) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        nkv = n_kv_heads or min(self.n_kv_heads, n_heads)
        nkv = max(1, min(nkv, n_heads))
        ne = self.n_experts and (n_experts if n_experts is not None
                                 else min(self.n_experts, 8))
        return dataclasses.replace(
            self, n_groups=n_groups,
            encoder_groups=(encoder_groups if encoder_groups is not None
                            else min(self.encoder_groups, n_groups)),
            d_model=d_model, n_heads=n_heads, n_kv_heads=nkv,
            head_dim=d_model // n_heads, d_ff=d_ff, vocab_size=vocab,
            n_experts=ne or 0, expert_d_ff=min(self.expert_d_ff, d_ff) if ne else 0,
            top_k=min(self.top_k, ne) if ne else 0,
            n_aux_tokens=min(self.n_aux_tokens, 16) or self.n_aux_tokens,
            **kw)


def get_config(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    if arch_id not in [a for a in ARCH_IDS] + ["paper_pim"]:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


# ---------------------------------------------------------------------------
# assigned input shapes (arch-independent), see brief
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
