"""whisper-small [audio enc-dec] — arXiv:2212.04356.

Conv frontend is a STUB per the brief: input_specs() provides precomputed
frame embeddings (B, 1500, d_model). Encoder: 12 bidirectional layers.
Decoder: 12 layers, each self-attn + cross-attn + MLP (kind="encdec").
RoPE replaces whisper's learned absolute positions (documented deviation).
"""
from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    group_spec=(LayerSpec(kind="encdec"),), n_groups=12,
    encoder_groups=12, aux_kind="audio", n_aux_tokens=1500,
    rope_theta=10000.0, act="gelu",
)
