"""olmoe-1b-7b [moe] — arXiv:2409.02060. 64 experts, top-8, MHA (kv=16)."""
from .base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    group_spec=(LayerSpec(kind="attn", moe=True),), n_groups=16,
    n_experts=64, top_k=8, expert_d_ff=1024, capacity_factor=1.25,
    rope_theta=10000.0, act="silu",
)
