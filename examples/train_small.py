"""End-to-end training driver example: a ~100M-param granite-family model on
the synthetic pipeline with checkpoints, restart safety and the straggler
watchdog. (Reduced geometry so it runs on CPU; the same driver lowers the
full configs on TPU meshes.)

Run:  PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    steps = "200"
    if "--steps" in sys.argv:
        steps = sys.argv[sys.argv.index("--steps") + 1]
    # d_model 768 x 12 groups ~= 100M params
    train.main(["--arch", "granite_3_2b", "--reduced",
                "--d-model", "768", "--n-groups", "12",
                "--vocab", "4096", "--seq", "256", "--batch", "8",
                "--steps", steps, "--lr", "1e-3",
                "--ckpt-dir", "/tmp/repro_train_small",
                "--save-every", "50", "--log-every", "10"])
