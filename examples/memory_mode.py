"""Memory-mode example (paper §3.1): NB-LDPC protecting *stored* data via
the `repro.memory` subsystem.

Part 1 — `ProtectedMemoryArray`: tensors are packed into GF(3) codewords on
write; MLC device faults (asymmetric level transitions, retention drift,
stuck-at cells) are injected through the channel models; reads correct
transparently under a write-back controller and a scrub sweep repairs the
whole array in place.

Part 2 — the framework's own checkpoints: protected payloads ride the same
subsystem, and storage rot is injected with `inject_storage_faults` (the
channel API — no hand-editing of the on-disk layout, so this example
survives checkpoint-format changes).

Run:  PYTHONPATH=src python examples/memory_mode.py
"""
import tempfile

import numpy as np

from repro import checkpoint as ckpt
from repro.memory import (Compose, ProtectedMemoryArray, RetentionDrift,
                          StuckAt, asymmetric_adjacent)

# a physics stack: adjacent-level confusion + slow drift + a few dead cells
device_noise = Compose(
    asymmetric_adjacent(3, eps_up=2e-3, eps_down=1e-3),
    RetentionDrift(3, rate=5e-7, rest_level=0),     # ~0.2%/h of aging
    StuckAt(3, fraction=2e-4, stuck_level=0, seed=7),
)

# ---- Part 1: protected array + controller policies -------------------------
mem = ProtectedMemoryArray("wl320_r08", controller="writeback", chunk_size=128)
kv = np.linspace(-2, 2, 8192).astype(np.float32).reshape(128, 64)
mem.write("kv_cache", kv)

n_cells = mem.inject(device_noise, t=3600.0)            # one hour of aging
print(f"injected {n_cells} faulty cells into stored codewords")

out = mem.read("kv_cache")
st = mem.stats
print(f"read-back exact={np.array_equal(out, kv)}  "
      f"(detected={st.detected} corrected={st.corrected} "
      f"uncorrectable={st.uncorrectable} writebacks={st.writebacks})")
assert np.array_equal(out, kv)

mem.inject(device_noise, t=3600.0)                      # keep aging
report = mem.scrub()
print(f"scrub: {report['words_scanned']} words scanned, "
      f"{report['corrected']} repaired in place, "
      f"{report['bandwidth_cells_per_s'] / 1e6:.2f} Mcells/s")

# ---- Part 2: NB-LDPC-protected checkpoints ---------------------------------
with tempfile.TemporaryDirectory() as d:
    tree = {"layer/w": np.linspace(-2, 2, 4096).astype(np.float32).reshape(64, 64),
            "layer/b": np.zeros(64, np.float32)}
    path = ckpt.save_checkpoint(d, 100, tree, protect=True)
    print(f"saved NB-LDPC-protected checkpoint: {path}")

    n = ckpt.inject_storage_faults(d, device_noise, key=0, t=3600.0)
    print(f"injected {n} faulty cells into the stored checkpoint")

    out, man = ckpt.restore_checkpoint(d, tree)
    ok = all(np.array_equal(out[k], tree[k]) for k in tree)
    cs = man["correction_stats"]
    print(f"restore with FBP correction: exact={ok} "
          f"(corrected {cs['corrected']}/{cs['detected']} flagged words)")
    assert ok
    print("OK: memory-mode NB-LDPC recovered the corrupted checkpoint.")
