"""Memory-mode example (paper §3.1): NB-LDPC protecting *stored* data — here
the framework's own checkpoints. Bit flips injected into the stored codewords
are corrected transparently on restore.

Run:  PYTHONPATH=src python examples/memory_mode.py
"""
import glob
import tempfile

import numpy as np

from repro import checkpoint as ckpt

with tempfile.TemporaryDirectory() as d:
    tree = {"layer/w": np.linspace(-2, 2, 4096).astype(np.float32).reshape(64, 64),
            "layer/b": np.zeros(64, np.float32)}
    path = ckpt.save_checkpoint(d, 100, tree, protect=True)
    print(f"saved NB-LDPC-protected checkpoint: {path}")

    # simulate storage corruption: flip symbols in the stored codewords
    n_flips = 24
    rng = np.random.default_rng(0)
    for fn in glob.glob(d + "/step_*/*.prot.npz"):
        z = dict(np.load(fn))
        enc = z["enc"].copy()
        for _ in range(n_flips // 2):
            r, c = rng.integers(0, enc.shape[0]), rng.integers(0, enc.shape[1])
            enc[r, c] = (enc[r, c] + rng.integers(1, 3)) % 3
        np.savez(fn[:-4], **{**z, "enc": enc})
    print(f"injected ~{n_flips} symbol flips into stored codewords")

    out, man = ckpt.restore_checkpoint(d, tree)
    ok = all(np.array_equal(out[k], tree[k]) for k in tree)
    print(f"restore with FBP correction: exact={ok}")
    assert ok
    print("OK: memory-mode NB-LDPC recovered the corrupted checkpoint.")
