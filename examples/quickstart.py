"""Quickstart: the paper's NB-LDPC arithmetic code in 60 lines.

1. build a GF(3) code, 2. encode a weight matrix (check columns ride along),
3. run the PIM MAC with injected analog faults (Eq. 4), 4. detect via the
syndrome (Eq. 5), 5. correct with the FBP decoder (§3.2), 6. compare.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PIMConfig, ProtectionConfig, encode_weight_matrix,
                        get_code, pim_mac, protected_pim_matmul, syndrome)

rng = np.random.default_rng(0)
code = get_code("wl160_r08")          # 160 GF(3) symbols, rate 0.8
print(f"code: n={code.n} k={code.k} GF({code.p}) rate={code.rate:.2f} "
      f"(paper §3: H_G·H_Cᵀ=0)")

# --- ternary weights (differential mapping, §3.3) + encode -----------------
n_in, n_out = 96, 2 * code.k
W = jnp.asarray(rng.integers(-1, 2, (n_in, n_out)), jnp.int32)
W_enc = encode_weight_matrix(W, code)
print(f"stored array: {W.shape} -> {W_enc.shape} "
      f"(+{W_enc.shape[1] - n_out} check columns)")

# --- PIM MAC with faults (the analog path is noisy, Fig. 1a) ---------------
x = jnp.asarray(rng.integers(-1, 2, (8, n_in)), jnp.int32)
exact = x @ W
noisy_cfg = PIMConfig(output_error_rate=0.01, output_error_mag=1)
Y_noisy = pim_mac(x, W_enc, noisy_cfg, key=jax.random.PRNGKey(7))

# --- detect (Eq. 5): syndrome of the *MAC output*, dataflow uninterrupted --
synd = syndrome(Y_noisy.reshape(-1, code.n) % code.p, code)
n_bad_words = int((np.asarray(synd) != 0).any(-1).sum())
print(f"syndrome flags {n_bad_words}/{synd.shape[0]} MAC output words")

# --- correct (§3.2: LLV init -> FBP iterations -> reinterpret) -------------
res = protected_pim_matmul(x, W_enc, code,
                           ProtectionConfig(mode="correct", n_iters=10,
                                            damping=0.3),
                           noisy_cfg, key=jax.random.PRNGKey(7))

raw = protected_pim_matmul(x, W_enc, code, ProtectionConfig(mode="off"),
                           noisy_cfg, key=jax.random.PRNGKey(7))
err_before = float((np.asarray(raw.y) != np.asarray(exact)).mean())
err_after = float((np.asarray(res.y) != np.asarray(exact)).mean())
print(f"integer error rate: {err_before:.4f} -> {err_after:.4f} "
      f"({err_before / max(err_after, 1e-9):.1f}x improvement)")
assert err_after < err_before
print("OK: NB-LDPC corrected the PIM MAC without interrupting the dataflow.")
