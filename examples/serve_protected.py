"""Protected serving example: batched generation on simulated PIM hardware
with the paper's fault model injected, NB-LDPC correcting every target
projection on the fly (the paper's deployment scenario).

Run:  PYTHONPATH=src python examples/serve_protected.py
"""
from repro.launch import serve

if __name__ == "__main__":
    print("=== clean PIM (protection on, no faults) ===")
    serve.main(["--arch", "paper_pim", "--reduced", "--batch", "4",
                "--prompt-len", "16", "--gen", "8", "--protect"])
    print("\n=== faulty PIM (rate 1e-3) + NB-LDPC correction ===")
    serve.main(["--arch", "paper_pim", "--reduced", "--batch", "4",
                "--prompt-len", "16", "--gen", "8", "--protect",
                "--fault-rate", "1e-3"])
