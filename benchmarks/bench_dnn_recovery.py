"""Paper Fig. 6(c): end-to-end DNN accuracy on noisy PIM with/without ECC.

The paper runs ResNet-34/ImageNet (ternary weights, binary activations on the
PIM layers). This container is offline, so we apply the IDENTICAL protocol to
an in-framework model: a small LM trained on the synthetic pipeline, with the
target projections executed on the simulated PIM (ternary weights, integer
activations) under the paper's fault model (fixed bit/symbol flip probability
during computation), with and without NB-LDPC correction. The metric is
next-token top-1 accuracy vs the fault-free run — the LM analogue of
classification accuracy recovery."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import PIMSpec
from repro.core.context import PIMContext
from repro.data import DataConfig, TokenPipeline
from repro.models import forward, init_params
from repro.launch import train as train_mod

FAULT_RATES = [1e-3, 3e-4, 1e-4, 1e-5]


def _train_small(steps=60, seed=0):
    ckpt = "/tmp/repro_bench_dnn"
    import shutil, os
    shutil.rmtree(ckpt, ignore_errors=True)
    train_mod.main(["--arch", "granite_3_2b", "--reduced", "--steps",
                    str(steps), "--batch", "8", "--seq", "64",
                    "--d-model", "128", "--n-groups", "2", "--lr", "5e-3",
                    "--ckpt-dir", ckpt, "--save-every", str(steps - 1),
                    "--log-every", "1000", "--seed", str(seed)])
    return ckpt


def main(quick: bool = False):
    steps = 40 if quick else 60
    ckpt_dir = _train_small(steps=steps)

    cfg = get_config("granite_3_2b").reduced(n_groups=2, d_model=128,
                                             n_heads=2, d_ff=512)
    from repro import checkpoint as ckpt
    params0 = init_params(jax.random.PRNGKey(0), cfg)
    from repro.optim import make_optimizer
    state, _ = ckpt.restore_checkpoint(
        ckpt_dir, {"params": params0,
                   "opt": make_optimizer("adamw", 1e-3).init(params0)})
    params = state["params"]

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    batch = next(TokenPipeline(dcfg, step=500))          # held-out step
    tokens = jnp.asarray(batch["tokens"])
    labels = np.asarray(batch["labels"])

    spec = PIMSpec(enabled=True, code_name="wl40_r08", mode="correct",
                   n_iters=6, damping=0.3, targets=("mlp_down", "attn_o"))
    base_ctx = PIMContext(spec)

    def top1(logits):
        return (np.asarray(jnp.argmax(logits, -1)) == labels).mean()

    clean = top1(forward(params, cfg, tokens))
    rows = [{"bench": "dnn_fig6c", "fault_rate": 0.0, "mode": "clean",
             "top1": float(clean)}]
    rates = FAULT_RATES[:2] if quick else FAULT_RATES[:3]
    for fr in rates:
        for mode in ("off", "correct"):
            ctx = PIMContext(dataclasses.replace(spec, mode=mode))
            ctx = ctx.with_faults(jax.random.PRNGKey(11), fr)
            acc = top1(forward(params, cfg, tokens, pim_ctx=ctx))
            rows.append({"bench": "dnn_fig6c", "fault_rate": fr,
                         "mode": "raw_pim" if mode == "off" else "nbldpc",
                         "top1": float(acc),
                         "recovered_vs_clean": float(acc / max(clean, 1e-9))})
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
