"""Paper Fig. 6(a): BER improvement vs word length (32..1024, rate 0.8).

Validation targets: longer codes correct better at fixed rate; the wl=1024
point improves raw BER 1e-5 by ~59.65x (paper: to 1.676e-7; exact value
depends on the random H draw — we validate the order of magnitude)."""
from __future__ import annotations

import numpy as np

from repro.core import get_code
from .ber_common import ber_curves

RAW_BERS = [1e-3, 3e-4, 1e-4, 3e-5, 1e-5]
WORDLENS = {"wl32_r08": 32, "wl64_r08": 64, "wl128_r08": 128,
            "wl256_r08": 256, "wl512_r08": 512, "wl1024_r08": 1024}


def main(quick: bool = False):
    rows = []
    names = (["wl64_r08", "wl256_r08", "wl1024_r08"] if quick
             else list(WORDLENS))
    trials = 48 if quick else 96
    for name in names:
        code = get_code(name)
        curves, _prof = ber_curves(code, RAW_BERS, trials=trials,
                                   max_errors=10 if quick else 12)
        for eps, post in curves["word"].items():
            post_info = curves["info"][eps]       # paper Fig. 6 is data BER
            rows.append({"bench": "wordlen_fig6a", "code": name,
                         "n": code.n, "raw_ber": eps, "post_ber": post,
                         "post_ber_info": post_info,
                         "improvement": eps / max(post, 1e-12),
                         "improvement_info": eps / max(post_info, 1e-12)})
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
