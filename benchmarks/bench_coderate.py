"""Paper Fig. 6(b): BER vs code rate at fixed word length 512
(rates 0.33 / 0.5 / 0.67 / 0.8). Lower rate => more redundancy => better
correction, at decoding-overhead cost."""
from __future__ import annotations

from repro.core import get_code
from .ber_common import ber_curve

RAW_BERS = [1e-3, 3e-4, 1e-4, 3e-5, 1e-5]
RATES = ["wl512_r033", "wl512_r05", "wl512_r067", "wl512_r08"]


def main(quick: bool = False):
    rows = []
    names = ["wl512_r033", "wl512_r08"] if quick else RATES
    trials = 48 if quick else 96
    for name in names:
        code = get_code(name)
        curve, _ = ber_curve(code, RAW_BERS, trials=trials,
                             max_errors=10 if quick else 14)
        for eps, post in curve.items():
            rows.append({"bench": "coderate_fig6b", "code": name,
                         "rate": round(code.rate, 3), "raw_ber": eps,
                         "post_ber": post,
                         "improvement": eps / max(post, 1e-12)})
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
