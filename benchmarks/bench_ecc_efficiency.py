"""Paper Table 2: ECC power efficiency + maximum word length + MTE vs the
baseline PIM ECC designs.

- "This work": the calibrated cycle/energy model (effmodel.py) at the
  comparison point (row parallelism 4), word length 256.
- MTE (maximum tolerable errors): measured on OUR decoder by conditional
  error injection — the largest m with >= 95% full-word correction.
- Baselines: published efficiency numbers from the paper's Table 2 plus the
  *behavioural* MTE of our reimplementations (core/baselines.py)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import decode_integers, encode_words, get_code
from .effmodel import (DecoderDesign, PROTOTYPE, efficiency_mbps_per_w,
                       power_w)

PAPER_TABLE2 = {
    "DAC22_successive": {"eff": 386.82, "mwl": 32, "mte": 3, "row_par": 8},
    "ASSCC21_secded": {"eff": 35.92, "mwl": 32, "mte": 1, "row_par": 4},
    "ESSCIRC22_modulo": {"eff": 88.47, "mwl": 25, "mte": 1, "row_par": 7},
}


def measured_mte(code_name: str, thresh: float = 0.95, trials: int = 64,
                 max_m: int = 12, seed: int = 0) -> int:
    rng = np.random.default_rng(seed)
    code = get_code(code_name)
    mte = 0
    for m in range(1, max_m + 1):
        w = jnp.asarray(rng.integers(0, code.p, (trials, code.k)), jnp.int32)
        cw = np.asarray(encode_words(w, code))
        y = cw.copy()
        for b in range(trials):
            idx = rng.choice(code.n, m, replace=False)
            y[b, idx] += rng.choice([-1, 1], m)
        yc, _ = decode_integers(code, jnp.asarray(y), n_iters=12, damping=0.3)
        ok = np.all(np.asarray(yc) == cw, axis=1).mean()
        if ok >= thresh:
            mte = m
        else:
            break
    return mte


def main(quick: bool = False):
    rows = []
    # this work @ comparison point: power measured at row parallelism 4
    design = DecoderDesign(n_vi=288, n_va=256, n_ci=1, n_ca=51, d_c=16,
                           n_p=4, c_p=10, rate=0.8, n_iters=4)
    eff = efficiency_mbps_per_w(PROTOTYPE, 71.0)
    mte = measured_mte("wl256_r08", trials=32 if quick else 64,
                       max_m=8 if quick else 12)
    best_base = max(v["eff"] for v in PAPER_TABLE2.values())
    rows.append({"bench": "table2", "design": "this_work_nbldpc",
                 "eff_mbps_w": round(eff, 2), "mwl_bits": 256,
                 "mte_measured": mte,
                 "row_parallelism": "arbitrary",
                 "improvement_vs_best": round(eff / best_base, 3)})
    for name, v in PAPER_TABLE2.items():
        rows.append({"bench": "table2", "design": name,
                     "eff_mbps_w": v["eff"], "mwl_bits": v["mwl"],
                     "mte_published": v["mte"],
                     "row_parallelism": v["row_par"],
                     "improvement_vs_best": round(v["eff"] / best_base, 3)})
    # long-code headline: wl1024 @ r0.88 exists and corrects >= 8 errors
    if not quick:
        mte1024 = measured_mte("wl1024_r08", trials=32, max_m=10)
        rows.append({"bench": "table2", "design": "this_work_wl1024_r08",
                     "mwl_bits": 1024, "mte_measured": mte1024})
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
