"""Protected KV-cache serving benchmark: end-to-end quality and throughput
of NB-LDPC memory-mode protection under live decode.

Three measurement families:

- **encode parity** — device-encoded pages (`PagedProtectedStore` through
  the Pallas `encode_words` path) must decode bit-exactly against the host
  `np_encode_words` oracle, for EVERY registry code (the two-backend
  interop contract);
- **throughput** — tokens/s of teacher-forced decode with the protected KV
  path vs the unprotected dense cache (same eager driver), plus the
  decode-overlap ablation: refill latency of the corrupted cache through
  the double-buffered pipeline vs synchronous whole-cache decode;
- **quality** — perplexity of a fixed continuation served from a corrupted
  KV store at raw BER eps, for corrected (protected) vs raw-level
  (unprotected) reads, against the clean-quantized reference. Protection
  must be strictly closer to the reference.

A fourth **telemetry** section replays a short corrupted serve under the
ambient observability layer (`repro.obs`): KV freeze/inject events land in
a Chrome trace, detection counters and RAS estimates land in a metrics
snapshot, and both are written as artifacts when `--trace` / `--metrics`
paths are given.

CLI:  PYTHONPATH=src python -m benchmarks.bench_kv_serving
        [--quick] [--json PATH] [--rows PATH]
        [--trace PATH] [--metrics PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import get_code, np_encode_words
from repro.core.codes import REGISTRY
from repro.kernels.backend import policy_from_store_backend
from repro.memory import PagedProtectedStore, asymmetric_adjacent
from repro.models import (ProtectedKVConfig, decode_step, init_caches,
                          init_params, prefill)

from .rows import DEFAULT_PATH, append_rows


# ---------------------------------------------------------------------------
# encode parity: device pages vs host oracle, every registry code
# ---------------------------------------------------------------------------


def _parity_rows(n_words: int = 24, seed: int = 0):
    """Every registry code, BOTH encode routes (the Pallas kernel path —
    interpret-mode off-TPU — and the jnp oracle the CPU serving path uses)
    against the host `np_encode_words` oracle, decoded back bit-exactly."""
    rows = []
    rng = np.random.default_rng(seed)
    for name in sorted(REGISTRY):
        code = get_code(name)
        u = rng.integers(0, code.p, (n_words, code.k))
        host = np_encode_words(u, code)
        for backend in ("kernel", "ref"):
            st = PagedProtectedStore(code, page_words=max(8, n_words // 2),
                                     policy=policy_from_store_backend(backend))
            st.append_words(u)
            dev = st.export_words().astype(np.int64)
            ok = np.array_equal(dev, host)
            # decode the device-encoded pages: corrected symbols must
            # round-trip the info words bit-exactly
            back = np.asarray(st.read_info(0, n_words))
            ok = ok and np.array_equal(back, u)
            rows.append({"section": "encode_parity", "code": name,
                         "backend": backend, "n_words": n_words,
                         "pass": bool(ok)})
            assert ok, f"device encode != host oracle for {name}/{backend}"
    return rows


# ---------------------------------------------------------------------------
# serving harness
# ---------------------------------------------------------------------------


def _setup(quick: bool):
    cfg = get_config("paper_pim")
    if quick:
        cfg = cfg.reduced(n_groups=2, d_model=64, n_heads=4, d_ff=128)
        B, S, gen, page_tokens = 2, 32, 16, 8
    else:
        cfg = cfg.reduced(n_groups=4, d_model=128, n_heads=4, d_ff=256)
        B, S, gen, page_tokens = 4, 64, 32, 16
    key = jax.random.PRNGKey(0)
    # 3x-scaled random init: raw init gives near-uniform logits that barely
    # read the KV cache, so corruption effects drown in noise; the scaled
    # model is sharp (ppl ~40 on its own rollout vs ~vocab/π for raw init)
    # and its quality visibly collapses when the cache rots
    params = jax.tree.map(lambda t: t * 3.0, init_params(key, cfg))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab_size)
    # the scored continuation is the model's own greedy rollout from the
    # clean dense cache: it carries real signal (low NLL), so KV corruption
    # shows up as a perplexity hit instead of noise around uniform
    cont = _greedy_cont(params, cfg, prompts, gen)
    return cfg, params, prompts, cont, page_tokens


def _rehome(cfg, batch, max_seq, caches):
    """Pad prefill caches into max-seq decode buffers (serve.py's place)."""
    full = init_caches(cfg, batch, max_seq)
    return jax.tree.map(
        lambda d, s: s if d.shape == s.shape
        else jnp.pad(s, [(0, a - b) for a, b in zip(d.shape, s.shape,
                                                     strict=True)]),
        full, caches)


def _greedy_cont(params, cfg, prompts, gen):
    B, S = prompts.shape
    logits, caches = prefill(params, cfg, prompts)
    caches = _rehome(cfg, B, S + gen + 1, caches)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    outs = [tok]
    for i in range(gen - 1):
        logits, caches = decode_step(params, cfg, caches, tok,
                                     jnp.asarray(S + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


def _serve(params, cfg, caches, prompts, cont):
    """Teacher-forced decode over `cont`; returns (mean NLL of the forced
    tokens, elapsed seconds, tokens served, first-step logits)."""
    B, S = prompts.shape
    gen = cont.shape[1]
    tok = prompts[:, -1:]
    nll, first = [], None
    t0 = time.perf_counter()
    for i in range(gen):
        logits, caches = decode_step(params, cfg, caches, tok,
                                     jnp.asarray(S + i))
        if first is None:
            first = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits[:, 0].astype(jnp.float32), axis=-1)
        nll.append(-jnp.take_along_axis(logp, cont[:, i:i + 1], axis=-1))
        tok = cont[:, i:i + 1]
    nll = jax.block_until_ready(jnp.concatenate(nll, axis=1))
    dt = time.perf_counter() - t0
    return float(nll.mean()), dt, B * gen, first


def _throughput_rows(quick: bool, code_name: str):
    cfg, params, prompts, cont, page_tokens = _setup(quick)
    B, S = prompts.shape
    max_seq = S + cont.shape[1] + 1
    rows = []

    # unprotected dense cache, same eager python driver (the apples-to-
    # apples baseline: only the KV backing differs)
    _lg, dense = prefill(params, cfg, prompts)
    dense = _rehome(cfg, B, max_seq, dense)
    _serve(params, cfg, dense, prompts, cont[:, :2])      # warm caches
    _lg, dense = prefill(params, cfg, prompts)
    dense = _rehome(cfg, B, max_seq, dense)
    _nll, dt_dense, toks, _f = _serve(params, cfg, dense, prompts, cont)
    tps_dense = toks / dt_dense

    # jitted dense step (launch/serve.py's driver) as context: the ceiling
    # a fully-jittable cache admits
    _lg, densej = prefill(params, cfg, prompts)
    densej = _rehome(cfg, B, max_seq, densej)
    jstep = jax.jit(lambda c, t, pos: decode_step(params, cfg, c, t, pos))
    tok = prompts[:, -1:]
    lgj, densej = jstep(densej, tok, jnp.asarray(S))      # compile
    t0 = time.perf_counter()
    for i in range(cont.shape[1]):
        lgj, densej = jstep(densej, cont[:, i:i + 1], jnp.asarray(S + 1 + i))
    jax.block_until_ready(lgj)
    tps_dense_jit = toks / (time.perf_counter() - t0)

    # protected paged store, fused one-kernel read path (the default:
    # corrected GF pages + scales straight into ops.attend_protected)
    pkv = ProtectedKVConfig(code_name=code_name, page_tokens=page_tokens)
    _lg, pc = prefill(params, cfg, prompts, protected_kv=pkv,
                      max_seq=max_seq)
    # warm over the FULL continuation: the fused read compiles one
    # executable per page-count bucket, and the larger buckets only
    # appear late in generation — a short warmup would bill their
    # compiles to the timed run
    _serve(params, cfg, pc, prompts, cont)
    _lg, pc = prefill(params, cfg, prompts, protected_kv=pkv,
                      max_seq=max_seq)
    nll_f, dt_prot, toks, first_f = _serve(params, cfg, pc, prompts, cont)
    tps_prot = toks / dt_prot

    # unfused streaming ablation (per-page decode -> dequant -> jitted
    # online-softmax update): the exact-parity reference the fused kernel
    # must match bitwise AND beat on tokens/s
    pkv_u = ProtectedKVConfig(code_name=code_name, page_tokens=page_tokens,
                              fused=False)
    _lg, pcu = prefill(params, cfg, prompts, protected_kv=pkv_u,
                       max_seq=max_seq)
    _serve(params, cfg, pcu, prompts, cont)                # warm executables
    _lg, pcu = prefill(params, cfg, prompts, protected_kv=pkv_u,
                       max_seq=max_seq)
    nll_u, dt_unf, toks, first_u = _serve(params, cfg, pcu, prompts, cont)
    tps_unfused = toks / dt_unf
    fused_bitexact = bool(
        np.array_equal(np.asarray(first_f), np.asarray(first_u))
        and nll_f == nll_u)

    rows.append({"section": "throughput", "code": code_name,
                 "batch": B, "prompt": S, "gen": cont.shape[1],
                 "tokens_per_s_dense": round(tps_dense, 2),
                 "tokens_per_s_dense_jit": round(tps_dense_jit, 2),
                 "tokens_per_s_protected": round(tps_prot, 2),
                 "protected_slowdown": round(tps_dense / tps_prot, 3),
                 "kv_stats": pc.stats()})
    rows.append({"section": "fused", "code": code_name,
                 "batch": B, "prompt": S, "gen": cont.shape[1],
                 "tokens_per_s_fused": round(tps_prot, 2),
                 "tokens_per_s_unfused": round(tps_unfused, 2),
                 "fused_speedup": round(tps_prot / tps_unfused, 3),
                 "fused_bitexact": fused_bitexact})

    # decode-overlap ablation: refill the corrupted cache (first decode step
    # after injection pays the decode) via the scan-gated double-buffered
    # pipeline vs blocking whole-cache decode. Raw BER ~1e-4: the serving
    # regime where a good fraction of pages is still clean, so the scan
    # gate and the decode/attention interleave both get to work.
    ch = asymmetric_adjacent(get_code(code_name).p, 5e-5, 5e-5)
    lat = {}
    for mode, overlap in (("overlap", True), ("sync", False)):
        # fused=False: the overlap knob ablates the STREAMING refill
        # pipeline (decode of page i+1 overlapping attention on page i);
        # the fused path has no per-page consumer to overlap with
        pkv_m = ProtectedKVConfig(code_name=code_name,
                                  page_tokens=page_tokens, overlap=overlap,
                                  fused=False)
        _lg, pcm = prefill(params, cfg, prompts, protected_kv=pkv_m,
                           max_seq=max_seq)
        # warm EVERY store's scan + decode executable before timing (a
        # sparse warmup injection can leave some decoders untraced, and a
        # first-call trace would then be billed to the timed refill)
        for layer in pcm.layers.values():
            for store in (layer.k_store, layer.v_store):
                np.asarray(store._scanner()(store.page(0)))
                jax.block_until_ready(
                    store._decoder()(store.page(0))[1].symbols)
        pcm.inject(ch, key=7)
        _serve(params, cfg, pcm, prompts, cont[:, :1])
        reps = 3 if quick else 5
        t = 0.0
        for r in range(reps):
            pcm.inject(ch, key=100 + r)
            t0 = time.perf_counter()
            logits, pcm = decode_step(params, cfg, pcm, prompts[:, -1:],
                                      jnp.asarray(S + 1 + r))
            jax.block_until_ready(logits)
            t += time.perf_counter() - t0
        lat[mode] = t / reps
    rows.append({"section": "overlap", "code": code_name,
                 "refill_s_overlap": round(lat["overlap"], 4),
                 "refill_s_sync": round(lat["sync"], 4),
                 "overlap_speedup": round(lat["sync"] / lat["overlap"], 3)})
    return rows, (tps_dense, tps_prot, tps_unfused, fused_bitexact, lat)


def _quality_rows(quick: bool, code_name: str, raw_bers):
    cfg, params, prompts, cont, page_tokens = _setup(quick)
    B, S = prompts.shape
    max_seq = S + cont.shape[1] + 1
    p = get_code(code_name).p
    keys = (11, 12, 13) if quick else (11, 12, 13, 14, 15)
    rows = []

    def serve_one(corrected, eps, key):
        """-> (ppl, first-step logits) for one injection draw."""
        pkv = ProtectedKVConfig(code_name=code_name, page_tokens=page_tokens,
                                corrected=corrected, n_iters=16)
        _lg, pc = prefill(params, cfg, prompts, protected_kv=pkv,
                          max_seq=max_seq)
        if eps:
            pc.inject(asymmetric_adjacent(p, eps, eps), key=key)
        nll, _dt, _toks, first = _serve(params, cfg, pc, prompts, cont)
        return float(np.exp(nll)), first

    ppl_ref, lg_ref = serve_one(True, 0.0, 0)   # clean quantized reference

    def stats(corrected, eps):
        ppls, mses = [], []
        for key in keys:
            ppl, lg = serve_one(corrected, eps, key)
            ppls.append(ppl)
            mses.append(float(jnp.mean((lg - lg_ref) ** 2)))
        return float(np.mean(ppls)), float(np.mean(mses))

    for eps in raw_bers:
        ppl_prot, mse_prot = stats(True, eps)
        ppl_raw, mse_raw = stats(False, eps)
        rows.append({
            "section": "quality", "code": code_name, "raw_ber": eps,
            "injection_draws": len(keys),
            "ppl_clean_quantized": round(ppl_ref, 4),
            "ppl_protected": round(ppl_prot, 4),
            "ppl_unprotected": round(ppl_raw, 4),
            "ppl_delta_protected": round(abs(ppl_prot - ppl_ref), 5),
            "ppl_delta_unprotected": round(abs(ppl_raw - ppl_ref), 5),
            "logit_mse_protected": round(mse_prot, 7),
            "logit_mse_unprotected": round(mse_raw, 7),
        })
    return rows


# ---------------------------------------------------------------------------
# telemetry: metrics snapshot + Chrome trace artifact for a corrupted serve
# ---------------------------------------------------------------------------


def _telemetry_rows(quick: bool, code_name: str, trace_path, metrics_path):
    """Short corrupted protected serve under the ambient observability
    layer. Freeze spans and inject markers from `repro.models.kv` land in
    the trace; a post-serve corrected sweep of every page feeds the
    detection counters and the RAS estimator; both exports are validated
    (and written when paths were given)."""
    from repro import obs

    cfg, params, prompts, cont, page_tokens = _setup(quick)
    B, S = prompts.shape
    gen = min(cont.shape[1], 4)
    max_seq = S + cont.shape[1] + 1
    code = get_code(code_name)
    pkv = ProtectedKVConfig(code_name=code_name, page_tokens=page_tokens)

    reg = obs.MetricsRegistry()
    tr = obs.Tracer()
    est = obs.ErrorRateEstimator()
    with obs.use_metrics(reg), obs.use_tracer(tr), obs.use_estimator(est):
        _lg, pc = prefill(params, cfg, prompts, protected_kv=pkv,
                          max_seq=max_seq)
        pc.inject(asymmetric_adjacent(code.p, 1e-3, 1e-3), key=3)
        with obs.span("kv_serving.serve", gen=gen):
            _serve(params, cfg, pc, prompts, cont[:, :gen])
        # scrub-style corrected sweep: every live page of every store goes
        # through the instrumented read path, so mem_detected/corrected and
        # the estimator's flag/stress EWMAs reflect the injected channel
        with obs.span("kv_serving.sweep"):
            for layer in pc.layers.values():
                for store in (layer.k_store, layer.v_store):
                    for i in range(store.n_pages):
                        store.read_page_corrected(i)
        est.publish(reg)

    snap = reg.snapshot()
    trace_doc = tr.to_chrome_trace(trace_path)
    trace_ok = bool(json.loads(json.dumps(trace_doc))["traceEvents"]
                    == trace_doc["traceEvents"])
    if metrics_path:
        reg.append_jsonl(metrics_path,
                         meta={"bench": "kv_serving", "section": "telemetry"})

    def total(name):
        ent = snap.get(name, {"series": []})
        return sum(r.get("value", 0.0) for r in ent["series"])

    detected, corrected = total("mem_detected"), total("mem_corrected")
    frozen = total("kv_pages_frozen")
    injected = total("kv_cells_injected")
    freeze_spans = len(tr.spans("kv.freeze"))
    ras = est.snapshot()
    seen = sum(e["words_seen"] for e in ras.values())
    flagged = sum(e["words_flagged"] for e in ras.values())
    row = {"section": "telemetry", "code": code_name,
           "pages_frozen": int(frozen),
           "cells_injected": int(injected),
           "detected": int(detected), "corrected": int(corrected),
           "freeze_spans": freeze_spans,
           "trace_events": len(trace_doc["traceEvents"]),
           "ras_regions": len(ras),
           "ras_flag_rate": round(flagged / seen, 6) if seen else 0.0,
           "pass": bool(trace_ok and frozen > 0 and freeze_spans > 0
                        and injected > 0 and detected > 0
                        and corrected >= detected * 0.5
                        and flagged > 0 and snap)}
    return [row]


def main(quick: bool = False, trace_path=None, metrics_path=None):
    code_name = "wl160_r08"
    rows = _parity_rows(n_words=16 if quick else 48)
    tput, (tps_dense, tps_prot, tps_unfused, fused_bitexact, lat) = \
        _throughput_rows(quick, code_name)
    rows += tput
    raw_bers = [1e-2] if quick else [1e-2, 1e-3]
    qual = _quality_rows(quick, code_name, raw_bers)
    rows += qual
    tel = _telemetry_rows(quick, code_name, trace_path, metrics_path)
    rows += tel
    at = next(r for r in qual if r["raw_ber"] == 1e-2)
    rows.append({
        "section": "acceptance", "code": code_name,
        "protected_slowdown": round(tps_dense / tps_prot, 3),
        "fused_speedup": round(tps_prot / tps_unfused, 3),
        "fused_bitexact": fused_bitexact,
        "overlap_speedup": round(lat["sync"] / lat["overlap"], 3),
        "ppl_delta_protected": at["ppl_delta_protected"],
        "ppl_delta_unprotected": at["ppl_delta_unprotected"],
        "telemetry_pass": tel[0]["pass"],
        "pass": bool(tps_prot * 2 >= tps_dense
                     and tps_prot > tps_unfused
                     and fused_bitexact
                     and lat["overlap"] < lat["sync"]
                     and at["ppl_delta_protected"]
                     < at["ppl_delta_unprotected"]
                     and tel[0]["pass"]),
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: tiny model, short continuation")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measurement rows as JSON")
    ap.add_argument("--rows", default=DEFAULT_PATH, metavar="PATH",
                    help="append standardized rows here ('' disables)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the telemetry section's Chrome trace JSON")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="append the telemetry metrics snapshot (JSONL)")
    args = ap.parse_args()
    if args.json:        # fail fast on an unwritable path, not after minutes
        with open(args.json, "a"):
            pass
    out = main(quick=args.quick, trace_path=args.trace,
               metrics_path=args.metrics)
    for row in out:
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, default=str)
    if args.rows:
        append_rows(args.rows, "kv_serving", out)
