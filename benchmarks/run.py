"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints one CSV-ish line per measurement and a per-bench validation summary
(EXPERIMENTS.md mirrors these numbers)."""
from __future__ import annotations

import argparse
import os
import time

BENCHES = [
    ("wordlen_fig6a", "benchmarks.bench_wordlen"),
    ("coderate_fig6b", "benchmarks.bench_coderate"),
    ("dnn_fig6c", "benchmarks.bench_dnn_recovery"),
    ("table2_efficiency", "benchmarks.bench_ecc_efficiency"),
    ("decoder_throughput_fig5", "benchmarks.bench_decoder_throughput"),
    ("memory_mode", "benchmarks.bench_memory_mode"),
    ("scrub_engine", "benchmarks.bench_scrub"),
    ("kv_serving", "benchmarks.bench_kv_serving"),
    ("multitenant", "benchmarks.bench_multitenant"),
    ("dse_fig7", "benchmarks.bench_dse"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    all_rows = {}
    for name, mod_name in BENCHES:
        if args.only and args.only not in name:
            continue
        mod = __import__(mod_name, fromlist=["main"])
        t0 = time.time()
        try:
            rows = mod.main(quick=args.quick)
        except Exception as e:                           # noqa: BLE001
            print(f"[{name}] FAILED: {type(e).__name__}: {e}", flush=True)
            continue
        dt = time.time() - t0
        all_rows[name] = rows
        print(f"\n=== {name} ({dt:.1f}s) ===", flush=True)
        for r in rows:
            print(",".join(f"{k}={v:.4g}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in r.items()), flush=True)

    # headline validations
    print("\n=== validation summary ===")
    wl = all_rows.get("wordlen_fig6a", [])
    big = [r for r in wl if r.get("n") == 1024 and r.get("raw_ber") == 1e-5]
    if big:
        post = float(big[0]["post_ber"])
        # conditional-MC resolution: one residual symbol in (trials x n)
        floor = 1.0 / (96 * 1024) * 0.05   # ~ pmf-weighted floor at 1e-5
        if post <= floor:
            print(f"wl1024 @ raw 1e-5: post < {floor:.1e} (no residual "
                  f"errors in any conditional trial) => improvement >= "
                  f"{1e-5 / floor:.0f}x — consistent with the paper's "
                  f"59.65x to 1.676e-7, below our measurement floor")
        else:
            print(f"wl1024 @ raw 1e-5: post={post:.3g} "
                  f"improvement={1e-5 / post:.1f}x "
                  f"(paper: 59.65x to 1.676e-7)")
    t2 = all_rows.get("table2_efficiency", [])
    ours = [r for r in t2 if r.get("design") == "this_work_nbldpc"]
    if ours:
        print(f"ECC efficiency: {ours[0]['eff_mbps_w']} Mbps/W, "
              f"{ours[0]['improvement_vs_best']}x best prior "
              f"(paper: 1152.00, 2.978x); MTE={ours[0]['mte_measured']} "
              f"(paper: 5 @ wl256)")
    mm = all_rows.get("memory_mode", [])
    acc = [r for r in mm if r.get("section") == "acceptance"]
    if acc:
        a = acc[0]
        print(f"memory mode @ raw {a['raw_ber']:.0e} (Hamming SECDED "
              f"saturated at {a['hamming_improvement']:.2f}x): NB-LDPC "
              f"improvement {a['nbldpc_improvement']:.1f}x over unprotected "
              f"(acceptance: >= 10x, pass={a['pass']})")
    kv = all_rows.get("kv_serving", [])
    kacc = [r for r in kv if r.get("section") == "acceptance"]
    if kacc:
        a = kacc[0]
        print(f"protected KV serving [{a['code']}]: slowdown "
              f"{a['protected_slowdown']}x vs same-driver dense, overlap "
              f"{a['overlap_speedup']}x vs sync whole-cache decode, ppl "
              f"delta {a['ppl_delta_protected']} protected vs "
              f"{a['ppl_delta_unprotected']} unprotected @ raw 1e-2 "
              f"(pass={a['pass']})")
    mt = all_rows.get("multitenant", [])
    macc = [r for r in mt if r.get("section") == "acceptance"]
    if macc:
        a = macc[0]
        print(f"multi-tenant serving [{a['code']}]: aggregate "
              f"{a['protected_tps_1']} -> {a['protected_tps_16']} tok/s "
              f"(1 -> 16 tenants, {a['scaling_1_to_16']}x, acceptance >= "
              f"2x), bit_exact={a['bit_exact']}, concurrent scrub cost "
              f"{a['scrub_cost_frac'] * 100:.1f}% (acceptance < 20%), "
              f"pass={a['pass']}")
    os.makedirs("results", exist_ok=True)
    from .rows import append_rows
    for name, rows in all_rows.items():
        append_rows("results/bench_rows.json", name, rows)


if __name__ == "__main__":
    main()
