"""Paper Fig. 7 + Fig. 4(c): design-space exploration.

(a) ECC power efficiency vs (beta*N_P*C_P/N_VI, N_CI/N_CA): the paper finds
    efficiency peaks when both ratios = 1 (no hardware stalls / no
    time-multiplex idling).
(b) FoM = efficiency / area vs N_CI: peaks at an intermediate N_CI because a
    CN costs 61.83x a VN (paper: sweet point N_CI = 8 at N_CA = 16... we
    sweep and report the argmax).
(c) Fig. 4(c): decoder area amortization across N_P cores sharing one
    decoder."""
from __future__ import annotations

import math

from .effmodel import (CN_OVER_VN, DecoderDesign, efficiency_mbps_per_w, fom)

N_CA = 16
N_VA = 256
D_C = 16
FREQ = 71.0


def main(quick: bool = False):
    rows = []
    # ---- (a) efficiency vs the two utilization ratios ----------------------
    n_p, c_p = 4, 10
    best = None
    for n_ci in ([1, 4, 16] if quick else [1, 2, 4, 8, 16]):
        for n_vi_scale in ([0.5, 1.0, 4.0] if quick
                           else [0.25, 0.5, 1.0, 2.0, 4.0]):
            d0 = DecoderDesign(n_vi=1, n_va=N_VA, n_ci=n_ci, n_ca=N_CA,
                               d_c=D_C, n_p=n_p, c_p=c_p)
            ideal_nvi = d0.beta * n_p * c_p            # u_v = 1 point
            n_vi = max(1, round(ideal_nvi / n_vi_scale))  # scale = target u_v
            d = DecoderDesign(n_vi=n_vi, n_va=N_VA, n_ci=n_ci, n_ca=N_CA,
                              d_c=D_C, n_p=n_p, c_p=c_p)
            eff = efficiency_mbps_per_w(d, FREQ)
            row = {"bench": "dse_fig7a", "n_ci": n_ci,
                   "nci_over_nca": round(n_ci / N_CA, 3),
                   "beta_npcp_over_nvi": round(d.u_v, 3),
                   "eff_mbps_w": round(eff, 2)}
            rows.append(row)
            if best is None or eff > best["eff_mbps_w"]:
                best = row
    rows.append({"bench": "dse_fig7a", "peak_at_vn_ratio":
                 best["beta_npcp_over_nvi"],
                 "peak_at_nci_over_nca": best["nci_over_nca"],
                 "validates_paper": bool(abs(best["beta_npcp_over_nvi"] - 1.0)
                                         < 0.35
                                         and best["nci_over_nca"] == 1.0)})

    # ---- (b) FoM vs N_CI ----------------------------------------------------
    # VN array at prototype scale (288): the decoder must hold a full codeword
    # for iterative decoding; CN area (61.83x a VN) then grows against a fixed
    # VN baseline, which is what produces the paper's interior FoM peak.
    fom_rows = []
    for n_ci in [1, 2, 4, 8, 16]:
        d = DecoderDesign(n_vi=288, n_va=N_VA, n_ci=n_ci, n_ca=N_CA,
                          d_c=D_C, n_p=n_p, c_p=c_p)
        f = fom(d, FREQ)
        fom_rows.append({"bench": "dse_fig7b", "n_ci": n_ci,
                         "fom_mbps_w_per_area": round(f, 4)})
    rows += fom_rows
    peak = max(fom_rows, key=lambda r: r["fom_mbps_w_per_area"])
    rows.append({"bench": "dse_fig7b", "fom_peak_nci": peak["n_ci"],
                 "validates_paper_interior_peak": 1 < peak["n_ci"] < 16})

    # ---- (c) Fig. 4(c): area amortization over shared cores ----------------
    pim_core_area_units = 4.0 * (288 + CN_OVER_VN)     # relative PIM core cost
    dec_area = 288 + CN_OVER_VN * 1
    for n_p_share in [1, 2, 4, 6, 8]:
        frac = dec_area / (dec_area + n_p_share * pim_core_area_units)
        rows.append({"bench": "fig4c_area_share", "n_p": n_p_share,
                     "decoder_area_fraction": round(frac, 4)})
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
