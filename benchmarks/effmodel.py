"""The paper's cycle/energy model for the NB-LDPC decoder hardware
(Sec. 4 / Sec. 6.4), calibrated to the silicon prototype's measured point.

Model structure (paper Table 1 parameters + the paper's own DSE reasoning):

- **Init phase** (input scheduler -> VN array): the PIM cores deliver
  N_P*C_P codeword symbols per read cycle; beta = (N_VA+N_CA)/(N_VA+2*N_CA)
  accounts for GF(3) check symbols occupying 2 bits. The VN utilization is
  u_v = beta*N_P*C_P / N_VI.
    u_v <= 1: the PIM feed sets the pace -> T_init = beta*N_VA/(N_P*C_P)
              cycles, and (1-u_v) of the VN array idles (power wasted);
    u_v > 1:  too few hardware VNs -> the PIM stalls; T_init stretches by
              u_v. Fixed overhead (scheduler/buffers/clock tree) does not
              shrink, so efficiency falls — hence the paper's peak at
              u_v = 1 ("no hardware suspended during initialization").
- **Iterative phase** (CN array): N_CA algorithmic CNs time-multiplexed onto
  N_CI hardware CNs, D_C+2 systolic FBP stages per CN pass:
      T_iter = n_iters * ceil(N_CA/N_CI) * (D_C + 2).
- **Power**: P = P_vn*(N_VI + 61.83*N_CI) + P_fixed, with CN = 61.83x VN
  (paper's synthesis result) and P_fixed a fixed fraction of the prototype's
  dynamic power. P_vn is the single calibrated constant: the prototype
  configuration (N_P=1, C_P=10, N_VI=288, N_CI=1, wl256 r0.8, 71 MHz) must
  hit the measured 1152.00 Mbps/W (paper Table 2 / Fig. 5c).
- **Area**: A = N_VI + 61.83*N_CI (units of one VN). FoM = efficiency / A
  (paper Fig. 7b)."""
from __future__ import annotations

import dataclasses
import math

CN_OVER_VN = 61.83          # paper: CN unit is 61.83x the VN unit
PROTO_EFF_MBPS_W = 1152.00  # measured best point
PROTO_FREQ_MHZ = 71.0
FIXED_FRACTION = 0.20       # fixed power as a fraction of prototype dynamic


@dataclasses.dataclass(frozen=True)
class DecoderDesign:
    n_vi: int               # hardware VNs
    n_va: int               # algorithmic VNs (codeword symbols)
    n_ci: int               # hardware CNs
    n_ca: int               # algorithmic CNs
    d_c: int = 16           # CN degree (systolic FBP stages)
    n_p: int = 1            # PIM cores sharing this decoder
    c_p: int = 10           # column parallelism per core
    rate: float = 0.8
    bits_per_symbol: int = 2  # GF(3) symbols ride on 2 bits
    n_iters: int = 4

    @property
    def beta(self) -> float:
        return (self.n_va + self.n_ca) / (self.n_va + 2 * self.n_ca)

    @property
    def u_v(self) -> float:
        """VN utilization during init (paper's beta*N_P*C_P/N_VI)."""
        return self.beta * self.n_p * self.c_p / self.n_vi

    def init_cycles(self) -> float:
        base = self.beta * self.n_va / (self.n_p * self.c_p)  # PIM feed pace
        return base * max(1.0, self.u_v)                       # stall stretch

    def iter_cycles(self) -> float:
        return self.n_iters * math.ceil(self.n_ca / self.n_ci) * (self.d_c + 2)

    def cycles_per_word(self) -> float:
        return self.init_cycles() + self.iter_cycles()

    def data_bits_per_word(self) -> float:
        return self.n_va * self.rate * self.bits_per_symbol

    def throughput_mbps(self, freq_mhz: float) -> float:
        words_per_s = freq_mhz * 1e6 / self.cycles_per_word()
        return words_per_s * self.data_bits_per_word() / 1e6

    def dyn_units(self) -> float:
        return self.n_vi + CN_OVER_VN * self.n_ci

    def area_units(self) -> float:
        return self.n_vi + CN_OVER_VN * self.n_ci


PROTOTYPE = DecoderDesign(n_vi=288, n_va=256, n_ci=1, n_ca=51, d_c=16,
                          n_p=1, c_p=10, rate=0.8, n_iters=4)

_FIXED_UNITS = FIXED_FRACTION * PROTOTYPE.dyn_units()


def _calibrate_unit_power() -> float:
    """mW per dynamic unit so the prototype hits 1152 Mbps/W at 71 MHz."""
    tput = PROTOTYPE.throughput_mbps(PROTO_FREQ_MHZ)
    units = PROTOTYPE.dyn_units() + _FIXED_UNITS
    return tput / (PROTO_EFF_MBPS_W * units * 1e-3)


UNIT_POWER_MW = _calibrate_unit_power()


def power_w(design: DecoderDesign, freq_mhz: float) -> float:
    units = design.dyn_units() + _FIXED_UNITS
    return UNIT_POWER_MW * units * 1e-3 * freq_mhz / PROTO_FREQ_MHZ


def efficiency_mbps_per_w(design: DecoderDesign, freq_mhz: float) -> float:
    return design.throughput_mbps(freq_mhz) / power_w(design, freq_mhz)


def fom(design: DecoderDesign, freq_mhz: float) -> float:
    """Paper Fig. 7(b): efficiency per area unit."""
    return efficiency_mbps_per_w(design, freq_mhz) / design.area_units()
