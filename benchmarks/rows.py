"""Standardized benchmark-row persistence.

`results/bench_rows.json` is a flat, append-only JSON list of row objects so
the perf trajectory across PRs/runs is machine-readable. Every row carries
at least {"bench": <name>, "schema_version": 1} plus the bench's metrics.
Legacy dict-of-lists files (the pre-subsystem layout) are flattened on
first append.
"""
from __future__ import annotations

import json
import os
from datetime import datetime, timezone
from collections.abc import Sequence

SCHEMA_VERSION = 1
DEFAULT_PATH = "results/bench_rows.json"


def standardize(rows: Sequence[dict], bench: str,
                ts: str | None = None) -> list[dict]:
    """Rows from one run share one `ts`, so consumers can group/select by
    run instead of guessing which of the accumulated rows is current."""
    if ts is None:
        ts = datetime.now(timezone.utc).isoformat(timespec="seconds")
    out = []
    for r in rows:
        r = dict(r)
        r.setdefault("bench", bench)
        r.setdefault("schema_version", SCHEMA_VERSION)
        r.setdefault("ts", ts)
        out.append(r)
    return out


def load_rows(path: str = DEFAULT_PATH) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):          # legacy {bench: [rows]} layout
        flat: list[dict] = []
        for name, rs in data.items():
            flat.extend(standardize(rs, name, ts=""))   # measured pre-schema
        return flat
    return data


def append_rows(path: str, bench: str, rows: Sequence[dict]) -> int:
    """Append standardized rows under `bench`; returns the new total."""
    existing = load_rows(path)
    existing.extend(standardize(rows, bench))
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(existing, f, indent=1, default=str)
    return len(existing)
