"""Scrub-engine benchmark: syndrome-scan bandwidth (cells/s), host BLAS vs
the fused Pallas device kernel, whole-array vs paged sweeps.

CLI:  PYTHONPATH=src python -m benchmarks.bench_scrub
        [--quick] [--json PATH] [--rows PATH]

Measures, per backend (host / device) and paging mode:
  - clean-array scrub bandwidth — the always-on cost, scan-only since
    nothing is flagged (the number that must be memory-bound for the
    paper's dataflow-friendly checking story);
  - corrupted-array scrub (scan + decode of flagged words + repair), with
    the parity check that host and device sweeps flag and repair
    identically.

`--quick` is the CI smoke mode. `--rows` (default results/bench_rows.json,
'' to disable) appends standardized rows for the perf trajectory.

On CPU hosts the "device" backend runs the kernel under the Pallas
interpreter — a correctness/parity point, not a speed point; the bandwidth
headline there is the host row. On TPU the device rows are the headline.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import get_code
from repro.memory import ProtectedMemoryArray, asymmetric_adjacent

from .rows import DEFAULT_PATH, append_rows


def _fill(mem: ProtectedMemoryArray, mbytes: float) -> int:
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, int(mbytes * 2 ** 20), np.uint8)
    mem.write("blob", payload)
    return mem.stored("blob").enc.shape[0]


def _bench_backend(code_name: str, backend: str, mbytes: float, eps: float,
                   page_words, chunk_size: int, repeats: int):
    """Rows for one (backend, paging) point + the repaired storage bytes
    for cross-backend parity checking."""
    code = get_code(code_name)
    from repro.kernels.backend import policy_from_scan_backend
    mem = ProtectedMemoryArray(code, controller="writeback",
                               chunk_size=chunk_size,
                               policy=policy_from_scan_backend(backend))
    n_words = _fill(mem, mbytes)
    cells = n_words * code.n

    # warm the cached scan/decode executables outside the timed region
    mem.scrub(page_words=page_words)

    t0 = time.perf_counter()
    for _ in range(repeats):
        rep = mem.scrub(page_words=page_words)
        assert rep["flagged"] == 0
    dt_clean = (time.perf_counter() - t0) / repeats

    mem.inject(asymmetric_adjacent(code.p, eps, eps),
               key=jax.random.PRNGKey(7))
    t0 = time.perf_counter()
    rep = mem.scrub(page_words=page_words)
    dt_dirty = time.perf_counter() - t0
    assert rep["backend"] == backend

    tag = {"code": code_name, "backend": backend,
           "page_words": page_words or 0, "mbytes": round(mbytes, 3),
           "words": n_words, "pages": rep["pages"]}
    rows = [
        dict(tag, section="scan_bandwidth", op="scrub_clean",
             seconds=round(dt_clean, 6),
             mcells_per_s=round(cells / dt_clean / 1e6, 3)),
        dict(tag, section="scan_bandwidth", op="scrub_corrupted",
             seconds=round(dt_dirty, 6),
             mcells_per_s=round(cells / dt_dirty / 1e6, 3),
             flagged=rep["flagged"], corrected=rep["corrected"],
             uncorrectable=rep["uncorrectable"]),
    ]
    return rows, mem.stored("blob").enc.copy()


def _bench_repair(code_name: str, mbytes: float, eps: float, page_words: int,
                  chunk_size: int, repeats: int):
    """Sparse-flag repair throughput: the coalesced `RepairQueue` pipeline
    (cross-page batching + bucketed decode executables + one sync per
    drain) against the per-page pad-to-chunk baseline, on identical
    corrupted storage. At raw BER ~1e-3 a page carries a handful of flags,
    so the baseline pays a full `chunk_size` decode and a host sync per
    page — the dispatch overhead this PR's pipeline removes."""
    code = get_code(code_name)
    from repro.kernels.backend import policy_from_scan_backend
    mem = ProtectedMemoryArray(code, controller="writeback",
                               chunk_size=chunk_size,
                               policy=policy_from_scan_backend("host"))
    n_words = _fill(mem, mbytes)
    mem.inject(asymmetric_adjacent(code.p, eps, eps),
               key=jax.random.PRNGKey(7))
    st = mem.stored("blob")
    snapshot = st.enc.copy()

    rows, runs = [], {}
    for coalesce in (False, True):
        # warm every executable this path will hit (flag pattern — hence
        # bucket mix — is deterministic, so warm == timed shapes)
        st.enc[:] = snapshot
        mem.scrub(page_words=page_words, coalesce=coalesce)
        best, rep = None, None
        for _ in range(repeats):
            st.enc[:] = snapshot             # restore outside the timer
            t0 = time.perf_counter()
            rep = mem.scrub(page_words=page_words, coalesce=coalesce)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        runs[coalesce] = (best, rep, st.enc.copy())
        row = {"section": "repair", "op": "sweep_coalesced" if coalesce
               else "sweep_baseline", "code": code_name,
               "page_words": page_words, "words": n_words,
               "flagged": rep["flagged"], "corrected": rep["corrected"],
               "uncorrectable": rep["uncorrectable"],
               "seconds": round(best, 6),
               "flags_per_s": round(rep["flagged"] / best, 1)}
        if coalesce:
            row.update(drains=rep["drains"],
                       repair_dispatch_rows=rep["repair_dispatch_rows"],
                       repair_pad_waste=round(rep["repair_pad_waste"], 4))
        rows.append(row)

    (dt_b, rep_b, enc_b), (dt_c, rep_c, enc_c) = runs[False], runs[True]
    identical = (np.array_equal(enc_b, enc_c)
                 and all(rep_b[k] == rep_c[k] for k in
                         ("flagged", "corrected", "uncorrectable")))
    speedup = dt_b / dt_c
    rows.append({
        "section": "repair", "op": "acceptance", "code": code_name,
        "repairs_identical": identical, "flagged": rep_c["flagged"],
        "baseline_seconds": round(dt_b, 6),
        "coalesced_seconds": round(dt_c, 6),
        "speedup": round(speedup, 3),
        "pass": identical and speedup >= 3.0,
    })
    assert identical, "coalesced sweep repaired storage differently"
    return rows


def main(quick: bool = False):
    if quick:
        code_name, mbytes, eps, chunk, page, reps = \
            "wl160_r08", 0.0625, 1e-3, 128, 64, 2
    else:
        code_name, mbytes, eps, chunk, page, reps = \
            "wl1024_r08", 4.0, 1e-4, 256, 2048, 3

    rows = []
    repaired = {}
    for backend in ("host", "device"):
        for page_words in (None, page):
            r, enc = _bench_backend(code_name, backend, mbytes, eps,
                                    page_words, chunk, reps)
            rows.extend(r)
            repaired[(backend, page_words)] = enc

    # acceptance: every (backend, paging) sweep repairs storage identically
    ref_key = ("host", None)
    identical = all(np.array_equal(repaired[ref_key], enc)
                    for enc in repaired.values())
    by = {(r["backend"], r["page_words"]): r["mcells_per_s"] for r in rows
          if r["op"] == "scrub_clean"}
    rows.append({
        "section": "acceptance", "code": code_name,
        "repairs_identical": identical,
        "host_mcells_per_s": by[("host", 0)],
        "device_mcells_per_s": by[("device", 0)],
        "device_is_interpreted": jax.default_backend() != "tpu",
        "pass": identical,
    })
    assert identical, "backend/paging sweeps repaired storage differently"

    rows.extend(_bench_repair(code_name, mbytes, eps, page, chunk, reps))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small code, tiny array")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measurement rows as JSON")
    ap.add_argument("--rows", default=DEFAULT_PATH, metavar="PATH",
                    help="append standardized rows here ('' disables)")
    args = ap.parse_args()
    if args.json:        # fail fast on an unwritable path, not after minutes
        with open(args.json, "a"):
            pass
    out = main(quick=args.quick)
    for row in out:
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, default=str)
    if args.rows:
        append_rows(args.rows, "scrub", out)
