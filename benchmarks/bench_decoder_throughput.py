"""Paper Fig. 5(b,c): decoder operating points.

Hardware Shmoo/power cannot be measured on CPU; we report
  (a) MEASURED decode throughput of the JAX decoder on this host
      (symbols/s and words/s vs batch, jnp path vs Pallas-interpret path),
  (b) MODELED power/efficiency across the prototype's 58-95 MHz frequency
      range from the calibrated energy model — clearly labeled modeled."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decode_integers, encode_words, get_code
from repro.kernels.ops import fbp_cn_batched
from .effmodel import PROTOTYPE, efficiency_mbps_per_w, power_w


def _measure(code, B, n_iters=4, cn_fbp=None, reps=3):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(0, code.p, (B, code.k)), jnp.int32)
    y = np.asarray(encode_words(w, code)).copy()
    y[:, 1] += 1
    y = jnp.asarray(y)

    fn = jax.jit(lambda yy: decode_integers(code, yy, n_iters=n_iters,
                                            cn_fbp=cn_fbp)[0])
    fn(y)[0].block_until_ready()                     # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(y)[0].block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return dt


def main(quick: bool = False):
    rows = []
    code = get_code("chip256_r08")
    for B in ([64] if quick else [16, 64, 256]):
        dt = _measure(code, B)
        rows.append({"bench": "decoder_throughput", "path": "jnp",
                     "batch": B, "words_per_s": round(B / dt, 1),
                     "msymbols_per_s": round(B * code.n / dt / 1e6, 3)})
    dt = _measure(code, 64, cn_fbp=fbp_cn_batched)
    rows.append({"bench": "decoder_throughput", "path": "pallas_interpret",
                 "batch": 64, "words_per_s": round(64 / dt, 1),
                 "note": "interpret mode exercises kernel semantics, not TPU "
                         "speed"})

    # modeled operating points across the measured Shmoo range
    for f in [58, 65, 71, 80, 88, 95]:
        rows.append({"bench": "fig5_modeled", "freq_mhz": f,
                     "power_mw_modeled": round(1e3 * power_w(PROTOTYPE, f), 2),
                     "eff_mbps_w_modeled":
                         round(efficiency_mbps_per_w(PROTOTYPE, f), 1)})
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
