"""Paper Fig. 5(b,c): decoder operating points + engine throughput.

Hardware Shmoo/power cannot be measured on CPU; we report
  (a) MEASURED decode throughput of the JAX decoder on this host across
      engine paths:
        jnp_ref       — seed Python-unrolled max-plus conv (baseline)
        jnp_vec       — vectorized gather-table engine (default hot path)
        jnp_vec_ee    — vectorized engine + per-codeword early exit
        sharded       — jnp_vec_ee shard_map'd over all local devices
        pallas_interpret — Pallas CN kernel in interpreter mode (semantics,
                           not TPU speed)
  (b) MODELED power/efficiency across the prototype's 58-95 MHz frequency
      range from the calibrated energy model — clearly labeled modeled.

CLI:  PYTHONPATH=src python -m benchmarks.bench_decoder_throughput
        [--quick] [--json PATH]
`--quick` is the CI smoke mode (small code, one batch); `--json` writes the
rows for artifact upload / results tracking.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decode_integers, encode_words, get_code
from repro.core.decode import _cn_fbp_jnp_ref
from repro.distributed.sharding import data_mesh, decode_sharded
from repro.kernels.ops import fbp_cn_batched
from .effmodel import PROTOTYPE, efficiency_mbps_per_w, power_w
from .rows import DEFAULT_PATH, append_rows


def _received_words(code, B):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(0, code.p, (B, code.k)), jnp.int32)
    y = np.asarray(encode_words(w, code)).copy()
    y[:, 1] += 1
    return jnp.asarray(y)


def _time(fn, y, reps=3):
    fn(y)[0].block_until_ready()                     # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(y)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps


def _measure(code, B, n_iters=8, cn_fbp=None, early_exit=False, reps=3,
             sharded=False):
    y = _received_words(code, B)
    if sharded:
        mesh = data_mesh()
        fn = jax.jit(lambda yy: decode_sharded(
            code, yy, mesh=mesh, n_iters=n_iters, early_exit=early_exit,
            cn_fbp=cn_fbp))
    else:
        fn = jax.jit(lambda yy: decode_integers(
            code, yy, n_iters=n_iters, cn_fbp=cn_fbp, early_exit=early_exit))
    return _time(fn, y, reps=reps)


def _row(code_name, code, path, B, dt, n_iters, **extra):
    return {"bench": "decoder_throughput", "path": path, "code": code_name,
            "n": code.n, "p": code.p, "batch": B, "n_iters": n_iters,
            "words_per_s": round(B / dt, 1),
            "msymbols_per_s": round(B * code.n / dt / 1e6, 4), **extra}


PATHS = [
    ("jnp_ref", dict(cn_fbp=_cn_fbp_jnp_ref)),
    ("jnp_vec", dict()),
    ("jnp_vec_ee", dict(early_exit=True)),
    ("sharded", dict(early_exit=True, sharded=True)),
]


def main(quick: bool = False):
    rows = []
    n_iters = 8
    points = ([("wl160_r08", [64])] if quick else
              [("chip256_r08", [64, 256]), ("wl1024_r08", [256])])
    for code_name, batches in points:
        code = get_code(code_name)
        for B in batches:
            base_dt = None
            for path, kw in PATHS:
                dt = _measure(code, B, n_iters=n_iters, **kw)
                extra = ({"devices": len(jax.devices())}
                         if path == "sharded" else {})
                row = _row(code_name, code, path, B, dt, n_iters, **extra)
                if path == "jnp_ref":
                    base_dt = dt
                else:
                    row["speedup_vs_ref"] = round(base_dt / dt, 2)
                rows.append(row)

    # Pallas CN kernel (interpret mode exercises semantics, not TPU speed)
    code = get_code("wl160_r08" if quick else "chip256_r08")
    dt = _measure(code, 64, n_iters=n_iters, cn_fbp=fbp_cn_batched)
    rows.append(_row("wl160_r08" if quick else "chip256_r08", code,
                     "pallas_interpret", 64, dt, n_iters,
                     note="interpret mode exercises kernel semantics, not "
                          "TPU speed"))

    # modeled operating points across the measured Shmoo range
    for f in [58, 65, 71, 80, 88, 95]:
        rows.append({"bench": "fig5_modeled", "freq_mhz": f,
                     "power_mw_modeled": round(1e3 * power_w(PROTOTYPE, f), 2),
                     "eff_mbps_w_modeled":
                         round(efficiency_mbps_per_w(PROTOTYPE, f), 1)})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small code, one batch size")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measurement rows as JSON")
    ap.add_argument("--rows", default=DEFAULT_PATH, metavar="PATH",
                    help="append standardized rows here ('' disables)")
    args = ap.parse_args()
    if args.json:        # fail fast on an unwritable path, not after minutes
        with open(args.json, "a"):
            pass
    out = main(quick=args.quick)
    for row in out:
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    if args.rows:
        append_rows(args.rows, "decoder_throughput", out)
