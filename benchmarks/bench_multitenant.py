"""Multi-tenant protected serving benchmark: aggregate throughput, tail
latency, and correctness of the continuous-batching engine
(`repro.serving.ServingEngine`) over the shared protected page pool.

Measurement families:

- **scaling** — aggregate tokens/s and p99 step latency vs concurrent
  sequence count (1/4/16, plus 64 in full mode), protected (pool-backed
  NB-LDPC pages) vs dense (same engine, raw KV rows). Batched slots amortize
  every executable across tenants, so aggregate throughput must rise
  steeply with occupancy (acceptance: >= 2x going 1 -> 16 protected).
- **bit-exactness** — every tenant of the 16-way protected run re-served
  alone in a same-shape engine must produce identical tokens (slot rows are
  computation-independent; quantize-on-freeze is deterministic).
- **scrub overhead** — the same noisy 16-way run with background pool
  scrubbing interleaved between steps (bounded cold-page sweeps) must keep
  >= 80% of the no-scrub aggregate throughput (acceptance: < 20% cost).
- **telemetry** — the same noisy 16-way scrubbing run with the full
  `repro.obs` stack installed (metrics registry + span tracer) vs telemetry
  off: aggregate tokens/s with telemetry on must stay >= 0.97x off
  (best-of-2 timed reps each side to defeat scheduler noise), the exported
  snapshot's per-tenant corrected gauges must equal `tenant_stats`, and the
  Chrome-trace JSON must round-trip `json.loads` with >= 1 `engine.step`
  span per step. A separate unmeasured rep runs the RAS-estimator-driven
  scrub schedule (adaptive interval + flag-hot page prioritization) and
  reports what it did. `--trace` / `--metrics` write the trace JSON and
  metrics JSONL artifacts.

CLI:  PYTHONPATH=src python -m benchmarks.bench_multitenant
        [--quick] [--json PATH] [--rows PATH]
        [--trace PATH] [--metrics PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.core import get_code
from repro.memory import ProtectedPagePool, asymmetric_adjacent
from repro.memory.paged import words_for_tensor
from repro.models import ProtectedKVConfig, init_params
from repro.serving import ServingEngine

from .rows import DEFAULT_PATH, append_rows

CODE_NAME = "wl160_r08"


def _setup(quick: bool):
    cfg = get_config("paper_pim")
    if quick:
        cfg = cfg.reduced(n_groups=2, d_model=64, n_heads=4, d_ff=128)
        S, gen, page_tokens = 12, 12, 8
        counts = [1, 4, 16]
    else:
        cfg = cfg.reduced(n_groups=4, d_model=128, n_heads=4, d_ff=256)
        S, gen, page_tokens = 24, 24, 8
        counts = [1, 4, 16, 64]
    # 3x-scaled init: sharp logits, so every tenant's rollout carries real
    # signal (same trick as bench_kv_serving)
    params = jax.tree.map(lambda t: t * 3.0,
                          init_params(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, S) for _ in range(counts[-1])]
    return cfg, params, prompts, gen, page_tokens, counts


def _make_pool(cfg, page_tokens: int, capacity: int) -> ProtectedPagePool:
    code = get_code(CODE_NAME)
    wpu = words_for_tensor((1, page_tokens, cfg.n_kv_heads, cfg.head_dim),
                           code.p, code.k)
    return ProtectedPagePool(code, page_words=wpu, capacity_pages=capacity)


def _timed_run(eng: ServingEngine, prompts, gen: int, *, inject_eps=0.0,
               inject_steps=(), max_steps=100000):
    """Submit one sequence per prompt, step to completion, return
    (results, tokens, elapsed_s, per-step seconds)."""
    for t, pr in enumerate(prompts):
        eng.submit(t, pr, max_new=gen)
    ch = (asymmetric_adjacent(get_code(CODE_NAME).p, inject_eps,
                              inject_eps / 2) if inject_eps else None)
    lats, tokens, steps = [], 0, 0
    t_start = time.perf_counter()
    while eng.waiting or any(s is not None for s in eng.slots):
        if steps >= max_steps:
            raise RuntimeError("run exceeded max_steps")
        t0 = time.perf_counter()
        rep = eng.step()
        lats.append(time.perf_counter() - t0)
        tokens += rep["tokens"]
        if ch is not None and steps in inject_steps:
            eng.inject(ch, key=50 + steps)
        steps += 1
    elapsed = time.perf_counter() - t_start
    results = {s.tenant: list(s.generated) for s in eng.sequences}
    return results, tokens, elapsed, lats


def _engine(params, cfg, n: int, gen: int, page_tokens: int, *,
            protected: bool, pool=None, scrub: bool = False,
            fused: bool = True):
    pkv = ProtectedKVConfig(code_name=CODE_NAME, page_tokens=page_tokens,
                            fused=fused)
    kw = dict(scrub_every=2, scrub_max_pages=8) if scrub else {}
    return ServingEngine(params, cfg, pkv=pkv, pool=pool, max_active=n,
                         max_seq=64, protected=protected, **kw)


def _p99_ms(lats) -> float:
    return round(float(np.percentile(np.asarray(lats) * 1e3, 99)), 2)


def main(quick: bool = False, trace_path=None, metrics_path=None):
    cfg, params, prompts, gen, page_tokens, counts = _setup(quick)
    n_layers = cfg.n_groups * len(cfg.group_spec)
    pages_per_seq = -(-(len(prompts[0]) + gen) // page_tokens)
    capacity = counts[-1] * pages_per_seq * 2 * n_layers + 8
    pool = _make_pool(cfg, page_tokens, capacity)   # shared: one executable
                                                    # set for every engine
    rows = []
    tps = {}

    for n in counts:
        for protected in (True, False):
            # warm the executables for this batch shape before timing
            warm = _engine(params, cfg, n, gen, page_tokens,
                           protected=protected, pool=pool if protected
                           else None)
            _timed_run(warm, prompts[:n], 3)
            eng = _engine(params, cfg, n, gen, page_tokens,
                          protected=protected, pool=pool if protected
                          else None)
            res, tokens, dt, lats = _timed_run(eng, prompts[:n], gen)
            tag = "protected" if protected else "dense"
            tps[(n, tag)] = tokens / dt
            rows.append({
                "section": "scaling", "mode": tag, "sequences": n,
                "prompt": len(prompts[0]), "gen": gen,
                "tokens": tokens,
                "tokens_per_s": round(tokens / dt, 2),
                "p99_step_ms": _p99_ms(lats),
                "mean_step_ms": round(float(np.mean(lats)) * 1e3, 2),
                "preemptions": eng.stats()["preemptions"],
            })
            if protected and n == 16:
                ref16 = res

    # bit-exactness: each of the 16 tenants re-served alone in a same-shape
    # engine (identical executables and page schedule, one occupied slot)
    bit_exact = True
    if 16 in counts:
        for t in range(16):
            solo = _engine(params, cfg, 16, gen, page_tokens,
                           protected=True, pool=pool)
            res, *_ = _timed_run(solo, [prompts[t]], gen)
            if res[0] != ref16[t]:
                bit_exact = False
                break
        rows.append({"section": "bit_exact", "sequences": 16,
                     "tenants_checked": 16, "pass": bool(bit_exact)})

    # fused vs unfused protected read at top occupancy: the batched
    # one-kernel GF-page attention (default) against the per-page
    # streaming ablation — same engine, same prompts, token streams must
    # be identical (the fused recurrence is the jitted streaming
    # recurrence by construction)
    hi = 16 if 16 in counts else counts[-1]
    fres = {}
    for fused in (True, False):
        # full-generation warm for BOTH sides: the fused read compiles one
        # executable per page-count bucket, and the larger buckets only
        # appear late in a run — the scaling loop's short warm would bill
        # those compiles to the fused timed run
        warm = _engine(params, cfg, hi, gen, page_tokens, protected=True,
                       pool=pool, fused=fused)
        _timed_run(warm, prompts[:hi], gen)
        eng = _engine(params, cfg, hi, gen, page_tokens, protected=True,
                      pool=pool, fused=fused)
        res, tokens, dt, _ = _timed_run(eng, prompts[:hi], gen)
        fres[fused] = (res, tokens / dt)
    tps_fused, tps_unfused = fres[True][1], fres[False][1]
    fused_match = fres[True][0] == fres[False][0]
    fused_speedup = tps_fused / tps_unfused
    rows.append({
        "section": "fused", "sequences": hi,
        "tokens_per_s_fused": round(tps_fused, 2),
        "tokens_per_s_unfused": round(tps_unfused, 2),
        "fused_speedup": round(fused_speedup, 3),
        "fused_outputs_match": bool(fused_match),
    })

    # scrub interleave: noisy 16-way serving with and without background
    # pool scrubbing (same injections), aggregate throughput ratio
    n_scrub = 16 if 16 in counts else counts[-1]
    scrub_res = {}
    for scrub in (False, True):
        # warm with an injection so the decoder executable compiles outside
        # the timed region (the clean scaling runs never decode)
        warm = _engine(params, cfg, n_scrub, gen, page_tokens,
                       protected=True, pool=pool, scrub=scrub)
        _timed_run(warm, prompts[:n_scrub], 3, inject_eps=2e-4,
                   inject_steps=(0,))
        eng = _engine(params, cfg, n_scrub, gen, page_tokens,
                      protected=True, pool=pool, scrub=scrub)
        # the pool (and its scrub counters) is shared bench-wide: delta them
        rounds0 = pool.stats.scrub_rounds
        repaired0 = pool.stats.scrub_corrected
        res, tokens, dt, lats = _timed_run(
            eng, prompts[:n_scrub], gen, inject_eps=2e-4,
            inject_steps=(2, 5))
        scrub_res[scrub] = (res, tokens / dt, lats,
                            pool.stats.scrub_rounds - rounds0,
                            pool.stats.scrub_corrected - repaired0)
    tps_noscrub, tps_scrub = scrub_res[False][1], scrub_res[True][1]
    scrub_cost = 1.0 - tps_scrub / tps_noscrub
    scrub_outputs_match = scrub_res[True][0] == scrub_res[False][0]
    rows.append({
        "section": "scrub", "sequences": n_scrub,
        "tokens_per_s_no_scrub": round(tps_noscrub, 2),
        "tokens_per_s_scrub": round(tps_scrub, 2),
        "scrub_cost_frac": round(scrub_cost, 4),
        "p99_step_ms_scrub": _p99_ms(scrub_res[True][2]),
        "scrub_rounds": scrub_res[True][3],
        "scrub_repaired_words": scrub_res[True][4],
        "outputs_match_no_scrub": bool(scrub_outputs_match),
    })

    # telemetry overhead + artifact validity: the scrub-shaped noisy run
    # with the observation pillars installed vs off. Timed best-of-2 per
    # side (full-generation runs; the scrub section already warmed this
    # exact engine shape). The estimator rides in a separate unmeasured
    # rep because it CHANGES the scrub schedule (adaptive interval,
    # prioritized page order) — a behavior change, not observation cost.
    def _telemetry_rep(telemetry: bool):
        eng = _engine(params, cfg, n_scrub, gen, page_tokens,
                      protected=True, pool=pool, scrub=True)
        if not telemetry:
            res, tokens, dt, lats = _timed_run(
                eng, prompts[:n_scrub], gen, inject_eps=2e-4,
                inject_steps=(2, 5))
            return eng, res, tokens / dt, lats, None, None
        reg, tr = obs.MetricsRegistry(), obs.Tracer()
        with obs.use_metrics(reg), obs.use_tracer(tr):
            res, tokens, dt, lats = _timed_run(
                eng, prompts[:n_scrub], gen, inject_eps=2e-4,
                inject_steps=(2, 5))
        eng.publish_metrics(reg)
        return eng, res, tokens / dt, lats, reg, tr

    off = max((_telemetry_rep(False) for _ in range(2)),
              key=lambda r: r[2])
    on = max((_telemetry_rep(True) for _ in range(2)),
             key=lambda r: r[2])
    eng_on, res_on, tps_on, lats_on, reg_on, tr_on = on
    tps_off_t = off[2]
    steps_on = len(lats_on)
    snap = reg_on.snapshot()
    trace_doc = tr_on.to_chrome_trace(trace_path)
    trace_ok = (json.loads(json.dumps(trace_doc))["traceEvents"]
                == trace_doc["traceEvents"])
    step_spans = len(tr_on.spans("engine.step"))
    tenant_gauges_match = all(
        obs.MetricsRegistry.value(snap, "tenant_corrected",
                                  layer="engine", tenant=str(t))
        == eng_on.tenant_stats(t)["corrected"]
        for t in range(n_scrub))
    corrected_total = sum(eng_on.tenant_stats(t)["corrected"]
                          for t in range(n_scrub))
    if metrics_path:
        reg_on.append_jsonl(metrics_path,
                            meta={"bench": "multitenant",
                                  "section": "telemetry"})

    # estimator-driven scrub demo (unmeasured): adaptive interval +
    # flag-hot prioritization, reported, not timed
    est = obs.ErrorRateEstimator()
    eng_est = _engine(params, cfg, n_scrub, gen, page_tokens,
                      protected=True, pool=pool, scrub=True)
    rounds0 = pool.stats.scrub_rounds
    with obs.use_estimator(est):
        res_est, *_ = _timed_run(eng_est, prompts[:n_scrub], gen,
                                 inject_eps=2e-4, inject_steps=(2, 5))
        adaptive_interval = est.adaptive_interval(2)
    est_rounds = pool.stats.scrub_rounds - rounds0
    est_snap = est.snapshot()
    telemetry_ratio = tps_on / tps_off_t
    rows.append({
        "section": "telemetry", "sequences": n_scrub,
        "tokens_per_s_off": round(tps_off_t, 2),
        "tokens_per_s_on": round(tps_on, 2),
        "telemetry_ratio": round(telemetry_ratio, 4),
        "steps": steps_on, "engine_step_spans": step_spans,
        "trace_json_valid": bool(trace_ok),
        "tenant_corrected_gauges_match": bool(tenant_gauges_match),
        "corrected_total": int(corrected_total),
        "outputs_match_off": bool(res_on == off[1]),
        "estimator_scrub_rounds": est_rounds,
        "estimator_adaptive_interval": adaptive_interval,
        "estimator_regions": len(est_snap),
        "pass": bool(telemetry_ratio >= 0.97 and trace_ok
                     and step_spans >= steps_on
                     and tenant_gauges_match),
    })
    telemetry_pass = rows[-1]["pass"]

    scaling = tps[(hi, "protected")] / tps[(1, "protected")]
    rows.append({
        "section": "acceptance", "code": CODE_NAME,
        "protected_tps_1": round(tps[(1, "protected")], 2),
        "protected_tps_16": round(tps[(hi, "protected")], 2),
        "scaling_1_to_16": round(scaling, 2),
        "dense_tps_16": round(tps[(hi, "dense")], 2),
        "bit_exact": bool(bit_exact),
        "fused_speedup": round(fused_speedup, 3),
        "fused_outputs_match": bool(fused_match),
        "scrub_cost_frac": round(scrub_cost, 4),
        "telemetry_ratio": round(telemetry_ratio, 4),
        "telemetry_pass": bool(telemetry_pass),
        "pass": bool(scaling >= 2.0 and bit_exact and scrub_cost < 0.2
                     and fused_match and scrub_outputs_match
                     and telemetry_pass),
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: tiny model, 1/4/16 sequences")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measurement rows as JSON")
    ap.add_argument("--rows", default=DEFAULT_PATH, metavar="PATH",
                    help="append standardized rows here ('' disables)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the telemetry run's Chrome trace JSON here "
                         "(open in ui.perfetto.dev)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="append the telemetry run's metrics snapshot as "
                         "one JSONL record here")
    args = ap.parse_args()
    if args.json:        # fail fast on an unwritable path, not after minutes
        with open(args.json, "a"):
            pass
    out = main(quick=args.quick, trace_path=args.trace,
               metrics_path=args.metrics)
    for row in out:
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, default=str)
    if args.rows:
        append_rows(args.rows, "multitenant", out)
