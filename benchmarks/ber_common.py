"""Compat shim: the semi-analytic BER machinery now lives in
`repro.memory.campaign` (library-grade, any scheme x any channel). This
module keeps the original helper signatures for existing benchmarks and
scripts, and additionally reports residuals over **info symbols** (the
paper's figures quote data BER) via `info=True` / `ber_curves`.
"""
from __future__ import annotations

import numpy as np

from repro.memory.campaign import (NBLDPCScheme, binom_pmf,  # noqa: F401
                                   conditional_residual_profile,
                                   mix_post_ber)
from repro.memory.channel import PlusMinusOne

__all__ = ["conditional_residuals", "binom_pmf", "post_ber", "ber_curve",
           "ber_curves"]


def _profile(code, max_errors, trials, n_iters, damping, seed, llv_mode):
    scheme = NBLDPCScheme(code, PlusMinusOne(0.0, p_field=code.p),
                          n_iters=n_iters, damping=damping,
                          llv_mode=llv_mode)
    return conditional_residual_profile(scheme, max_errors=max_errors,
                                        trials=trials, seed=seed)


def conditional_residuals(code, max_errors: int = 12, trials: int = 128,
                          n_iters: int = 12, damping: float = 0.3,
                          seed: int = 0, llv_mode: str = "manhattan",
                          info: bool = False):
    """r[m] for m = 0..max_errors under the ±1 integer-error channel.
    `info=True` measures over the k info symbols only (data BER)."""
    prof = _profile(code, max_errors, trials, n_iters, damping, seed,
                    llv_mode)
    return prof.r_info if info else prof.r_word


def post_ber(code, r: np.ndarray, eps: float) -> float:
    """Semi-analytic post-correction symbol error rate at raw BER eps."""
    return mix_post_ber(code.n, np.asarray(r), eps)


def ber_curve(code, raw_bers, **kw):
    r = conditional_residuals(code, **kw)
    return {eps: post_ber(code, r, eps) for eps in raw_bers}, r


def ber_curves(code, raw_bers, *, max_errors: int = 12, trials: int = 128,
               n_iters: int = 12, damping: float = 0.3, seed: int = 0,
               llv_mode: str = "manhattan"):
    """Both curves at once: {"word": {eps: post}, "info": {eps: post}} plus
    the underlying ResidualProfile — one set of decode runs, two reports."""
    prof = _profile(code, max_errors, trials, n_iters, damping, seed,
                    llv_mode)
    word = {eps: mix_post_ber(code.n, prof.r_word, eps) for eps in raw_bers}
    info = {eps: mix_post_ber(code.n, prof.r_info, eps) for eps in raw_bers}
    return {"word": word, "info": info}, prof
