"""Shared BER-measurement machinery.

Direct Monte-Carlo at raw BER 1e-5 would need ~10^8 decoded symbols to see a
single residual error, so we use the standard semi-analytic decomposition:

    post_BER(eps) = sum_m  Binom(n, eps, m) * r(m)

where r(m) = E[fraction of symbols still wrong after decoding | exactly m
injected symbol errors], estimated by conditional Monte-Carlo per m. This is
exact in expectation, covers every raw BER with ONE set of decode runs, and
matches how the paper's own low-BER points must have been produced
(their Fig. 6 reaches 1.7e-7).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decode_integers, encode_words, get_code


def conditional_residuals(code, max_errors: int = 12, trials: int = 128,
                          n_iters: int = 12, damping: float = 0.3,
                          seed: int = 0, llv_mode: str = "manhattan"):
    """r[m] for m = 0..max_errors; r[m] = mean residual symbol error rate
    after decoding words with exactly m random ±1 integer errors."""
    rng = np.random.default_rng(seed)
    r = np.zeros(max_errors + 1)
    for m in range(1, max_errors + 1):
        w = jnp.asarray(rng.integers(0, code.p, (trials, code.k)), jnp.int32)
        cw = np.asarray(encode_words(w, code))
        y = cw.copy()
        for b in range(trials):
            idx = rng.choice(code.n, m, replace=False)
            y[b, idx] += rng.choice([-1, 1], m)
        y_corr, _ = decode_integers(code, jnp.asarray(y), n_iters=n_iters,
                                    damping=damping, llv_mode=llv_mode)
        r[m] = float((np.asarray(y_corr) != cw).mean())
    return r


def binom_pmf(n: int, eps: float, m: int) -> float:
    if eps <= 0:
        return 1.0 if m == 0 else 0.0
    logp = (math.lgamma(n + 1) - math.lgamma(m + 1) - math.lgamma(n - m + 1)
            + m * math.log(eps) + (n - m) * math.log1p(-eps))
    return math.exp(logp)


def post_ber(code, r: np.ndarray, eps: float) -> float:
    """Semi-analytic post-correction symbol error rate at raw symbol BER eps."""
    total = 0.0
    for m in range(1, len(r)):
        total += binom_pmf(code.n, eps, m) * r[m]
    # tail beyond max_errors: assume decoder fails completely (r = m/n-ish);
    # upper-bound with eps (errors stay)
    tail = 1.0 - sum(binom_pmf(code.n, eps, m) for m in range(len(r)))
    total += max(tail, 0.0) * eps * 2
    return max(total, 0.0)


def ber_curve(code, raw_bers, **kw):
    r = conditional_residuals(code, **kw)
    return {eps: post_ber(code, r, eps) for eps in raw_bers}, r
