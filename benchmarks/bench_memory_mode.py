"""Memory-mode benchmark: `ProtectedMemoryArray` write/read/scrub throughput
per controller policy, plus the paper-style BER-improvement campaign
(the 59.65x-class comparison: NB-LDPC vs Hamming SECDED vs modulo checksum
vs unprotected under the ±1 cell-error channel).

CLI:  PYTHONPATH=src python -m benchmarks.bench_memory_mode
        [--quick] [--json PATH] [--rows PATH]

`--quick` is the CI smoke mode (small code, few trials). `--json` writes the
full output; `--rows` (default results/bench_rows.json, '' to disable)
appends standardized rows for the machine-readable perf trajectory.

The acceptance point: the smallest raw BER at which Hamming SECDED has
saturated (improvement <= 3x — double-bit errors dominate); there the
NB-LDPC wl1024 improvement over unprotected must be >= 10x.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.memory import (HammingSECDEDScheme, ModuloParityScheme,
                          NBLDPCScheme, ProtectedMemoryArray,
                          UnprotectedScheme, asymmetric_adjacent,
                          paper_schemes, run_campaign, select_acceptance_row)
from repro.core import get_code

from .rows import DEFAULT_PATH, append_rows


def _throughput_rows(code_name: str, mbytes: float, eps: float,
                     chunk_size: int):
    """Write / clean-read / corrupted-read / scrub timings per policy."""
    nbytes = int(mbytes * 2 ** 20)
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, nbytes, np.uint8)
    noise = asymmetric_adjacent(get_code(code_name).p, eps, eps)
    rows = []
    for policy in ("basic", "writeback", "scrub"):
        mem = ProtectedMemoryArray(code_name, controller=policy,
                                   chunk_size=chunk_size)
        if policy == "scrub":
            mem.controller.interval = 10 ** 9        # explicit scrubs only

        t0 = time.perf_counter()
        mem.write("blob", payload)
        t_write = time.perf_counter() - t0
        n_words = mem.stored("blob").enc.shape[0]

        t0 = time.perf_counter()
        out = mem.read("blob")
        t_clean = time.perf_counter() - t0
        assert np.array_equal(out, payload)

        mem.inject(noise, key=jax.random.PRNGKey(1))
        t0 = time.perf_counter()
        out = mem.read("blob")
        t_dirty = time.perf_counter() - t0
        assert np.array_equal(out, payload), f"{policy}: corrupted read wrong"

        mem.inject(noise, key=jax.random.PRNGKey(2))
        rep = mem.scrub()

        st = mem.stats
        for op, dt in (("write", t_write), ("read_clean", t_clean),
                       ("read_corrupted", t_dirty)):
            rows.append({
                "section": "throughput", "policy": policy, "op": op,
                "code": code_name, "mbytes": round(mbytes, 3),
                "mbytes_per_s": round(mbytes / dt, 3),
                "words_per_s": round(n_words / dt, 1),
            })
        rows.append({
            "section": "throughput", "policy": policy, "op": "scrub",
            "backend": rep["backend"], "pages": rep["pages"],
            "code": code_name, "words_scanned": rep["words_scanned"],
            "flagged": rep["flagged"], "corrected": rep["corrected"],
            "uncorrectable": rep["uncorrectable"],
            "mcells_per_s": round(rep["bandwidth_cells_per_s"] / 1e6, 3),
            "detected_total": st.detected, "corrected_total": st.corrected,
            "writebacks": st.writebacks,
        })
    return rows


def _campaign_rows(code_name: str, raw_bers, trials: int,
                   hamming_trials: int):
    code = get_code(code_name)
    out = run_campaign(paper_schemes(code), raw_bers, trials=trials,
                       hamming_trials=hamming_trials)
    rows = [{"section": "ber_campaign", "code": code_name, **r}
            for r in out["rows"]]
    acc = select_acceptance_row(out["rows"])
    if acc is not None:
        rows.append({"section": "acceptance", "code": code_name, **acc,
                     "pass": bool(acc["nbldpc_improvement"] >= 10.0)})
    return rows


def _mlc_rows(code_name: str, raw_bers, trials: int, hamming_trials: int):
    """GF(5)/GF(7) multi-level-cell end-to-end (ROADMAP item): the campaign
    under a TRUE multi-level `LevelTransition` channel — asymmetric
    adjacent-level confusion over all p levels (conditional error values
    drawn from the channel's own transition matrix, not uniform flips) —
    plus protected-array throughput under the same channel."""
    code = get_code(code_name)
    # 2:1 up/down asymmetry: conductance overlap is wider toward the
    # high-resistance state (see repro.memory.channel)
    ch = asymmetric_adjacent(code.p, 2e-3, 1e-3)
    schemes = [
        NBLDPCScheme(code, ch, n_iters=12, damping=0.3,
                     name=f"nbldpc_mlc_n{code.n}_gf{code.p}"),
        HammingSECDEDScheme(),
        ModuloParityScheme(k_data=32, q=code.p),
        UnprotectedScheme(),
    ]
    out = run_campaign(schemes, raw_bers, trials=trials,
                       hamming_trials=hamming_trials)
    rows = [{"section": "ber_campaign_mlc", "code": code_name,
             "gf": code.p, "channel": "asymmetric_adjacent(2e-3,1e-3)", **r}
            for r in out["rows"]]
    acc = select_acceptance_row(out["rows"])
    if acc is not None:
        rows.append({"section": "acceptance_mlc", "code": code_name,
                     "gf": code.p, **acc,
                     "pass": bool(acc["nbldpc_improvement"] >= 10.0)})
    rows += [{**r, "section": "throughput_mlc", "gf": code.p}
             for r in _throughput_rows(code_name, mbytes=0.125, eps=1e-3,
                                       chunk_size=128)]
    return rows


def main(quick: bool = False, mlc: bool = False):
    if quick:
        tput = _throughput_rows("wl160_r08", mbytes=0.125, eps=1e-3,
                                chunk_size=128)
        camp = _campaign_rows("wl256_r08", [1e-2, 1e-3, 1e-4],
                              trials=16, hamming_trials=512)
    else:
        tput = _throughput_rows("wl1024_r08", mbytes=4.0, eps=1e-4,
                                chunk_size=256)
        camp = _campaign_rows(
            "wl1024_r08",
            [3e-2, 2e-2, 1e-2, 5e-3, 2e-3, 1e-3, 3e-4, 1e-4, 1e-5],
            trials=64, hamming_trials=4096)
    out = tput + camp
    if mlc:
        bers = ([1e-2, 1e-3] if quick
                else [3e-2, 1e-2, 5e-3, 1e-3, 3e-4, 1e-4])
        trials = 16 if quick else 48
        for name in ("wl160_r08_gf5", "wl160_r08_gf7"):
            out += _mlc_rows(name, bers, trials=trials,
                             hamming_trials=512 if quick else 2048)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small code, few trials")
    ap.add_argument("--mlc", action="store_true",
                    help="add the GF(5)/GF(7) multi-level-cell end-to-end "
                         "sections (true LevelTransition channels)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measurement rows as JSON")
    ap.add_argument("--rows", default=DEFAULT_PATH, metavar="PATH",
                    help="append standardized rows here ('' disables)")
    args = ap.parse_args()
    if args.json:        # fail fast on an unwritable path, not after minutes
        with open(args.json, "a"):
            pass
    out = main(quick=args.quick, mlc=args.mlc)
    for row in out:
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, default=str)
    if args.rows:
        append_rows(args.rows, "memory_mode", out)
