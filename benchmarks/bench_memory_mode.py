"""Memory-mode benchmark: `ProtectedMemoryArray` write/read/scrub throughput
per controller policy, plus the paper-style BER-improvement campaign
(the 59.65x-class comparison: NB-LDPC vs Hamming SECDED vs modulo checksum
vs unprotected under the ±1 cell-error channel).

CLI:  PYTHONPATH=src python -m benchmarks.bench_memory_mode
        [--quick] [--json PATH] [--rows PATH]

`--quick` is the CI smoke mode (small code, few trials). `--json` writes the
full output; `--rows` (default results/bench_rows.json, '' to disable)
appends standardized rows for the machine-readable perf trajectory.

The acceptance point: the smallest raw BER at which Hamming SECDED has
saturated (improvement <= 3x — double-bit errors dominate); there the
NB-LDPC wl1024 improvement over unprotected must be >= 10x.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.memory import (ProtectedMemoryArray, asymmetric_adjacent,
                          paper_schemes, run_campaign, select_acceptance_row)
from repro.core import get_code

from .rows import DEFAULT_PATH, append_rows


def _throughput_rows(code_name: str, mbytes: float, eps: float,
                     chunk_size: int):
    """Write / clean-read / corrupted-read / scrub timings per policy."""
    nbytes = int(mbytes * 2 ** 20)
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, nbytes, np.uint8)
    noise = asymmetric_adjacent(3, eps, eps)
    rows = []
    for policy in ("basic", "writeback", "scrub"):
        mem = ProtectedMemoryArray(code_name, controller=policy,
                                   chunk_size=chunk_size)
        if policy == "scrub":
            mem.controller.interval = 10 ** 9        # explicit scrubs only

        t0 = time.perf_counter()
        mem.write("blob", payload)
        t_write = time.perf_counter() - t0
        n_words = mem.stored("blob").enc.shape[0]

        t0 = time.perf_counter()
        out = mem.read("blob")
        t_clean = time.perf_counter() - t0
        assert np.array_equal(out, payload)

        mem.inject(noise, key=jax.random.PRNGKey(1))
        t0 = time.perf_counter()
        out = mem.read("blob")
        t_dirty = time.perf_counter() - t0
        assert np.array_equal(out, payload), f"{policy}: corrupted read wrong"

        mem.inject(noise, key=jax.random.PRNGKey(2))
        rep = mem.scrub()

        st = mem.stats
        for op, dt in (("write", t_write), ("read_clean", t_clean),
                       ("read_corrupted", t_dirty)):
            rows.append({
                "section": "throughput", "policy": policy, "op": op,
                "code": code_name, "mbytes": round(mbytes, 3),
                "mbytes_per_s": round(mbytes / dt, 3),
                "words_per_s": round(n_words / dt, 1),
            })
        rows.append({
            "section": "throughput", "policy": policy, "op": "scrub",
            "backend": rep["backend"], "pages": rep["pages"],
            "code": code_name, "words_scanned": rep["words_scanned"],
            "flagged": rep["flagged"], "corrected": rep["corrected"],
            "uncorrectable": rep["uncorrectable"],
            "mcells_per_s": round(rep["bandwidth_cells_per_s"] / 1e6, 3),
            "detected_total": st.detected, "corrected_total": st.corrected,
            "writebacks": st.writebacks,
        })
    return rows


def _campaign_rows(code_name: str, raw_bers, trials: int,
                   hamming_trials: int):
    code = get_code(code_name)
    out = run_campaign(paper_schemes(code), raw_bers, trials=trials,
                       hamming_trials=hamming_trials)
    rows = [{"section": "ber_campaign", "code": code_name, **r}
            for r in out["rows"]]
    acc = select_acceptance_row(out["rows"])
    if acc is not None:
        rows.append({"section": "acceptance", "code": code_name, **acc,
                     "pass": bool(acc["nbldpc_improvement"] >= 10.0)})
    return rows


def main(quick: bool = False):
    if quick:
        tput = _throughput_rows("wl160_r08", mbytes=0.125, eps=1e-3,
                                chunk_size=128)
        camp = _campaign_rows("wl256_r08", [1e-2, 1e-3, 1e-4],
                              trials=16, hamming_trials=512)
    else:
        tput = _throughput_rows("wl1024_r08", mbytes=4.0, eps=1e-4,
                                chunk_size=256)
        camp = _campaign_rows(
            "wl1024_r08",
            [3e-2, 2e-2, 1e-2, 5e-3, 2e-3, 1e-3, 3e-4, 1e-4, 1e-5],
            trials=64, hamming_trials=4096)
    return tput + camp


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small code, few trials")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measurement rows as JSON")
    ap.add_argument("--rows", default=DEFAULT_PATH, metavar="PATH",
                    help="append standardized rows here ('' disables)")
    args = ap.parse_args()
    if args.json:        # fail fast on an unwritable path, not after minutes
        with open(args.json, "a"):
            pass
    out = main(quick=args.quick)
    for row in out:
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, default=str)
    if args.rows:
        append_rows(args.rows, "memory_mode", out)
